//! Batched Serverless (§3, "Batch λ"): trigger a deployment only when a
//! batch of updates is waiting in the MQ (batch sizes per §6.3:
//! 2/10/100/100 for 10/100/1000/10000 parties), plus a flush once the
//! round's final update arrives.
//!
//! Batching amortizes deployment overheads ("ensures at least a batch of
//! updates to process") at the cost of latency: the paper observes Batch λ
//! latency is generally the worst of the dynamic strategies because the
//! tail updates wait for a batch to fill or for the end-of-round flush.
//!
//! Each trigger is its own serverless invocation (no warm reuse): the
//! deployment loads the current partial aggregate, folds its batch, and
//! checkpoints the partial back — so every batch pays cold start + state
//! in/out, which is exactly the amortization-vs-cost trade the paper
//! describes. Runs unmodified under the live wall-clock driver
//! (`fljit live --strategy batched`).

use super::{Ctx, RoundTracker, Strategy};
use crate::cluster::{Notification, TaskId, TaskSpec};
use crate::metrics::RoundRecord;

#[derive(Default)]
pub struct BatchedServerless {
    tracker: RoundTracker,
    /// Updates waiting for a batch trigger.
    buffered: usize,
    pool: Vec<TaskId>,
}

impl BatchedServerless {
    fn dispatch(&mut self, ctx: &mut Ctx, n_items: usize) {
        if n_items == 0 {
            return;
        }
        let items = vec![ctx.params.item; n_items];
        // One fresh serverless invocation per batch trigger: load the
        // partial aggregate, fold the batch, checkpoint the partial back.
        let task = ctx.cluster.submit(TaskSpec {
            job: ctx.params.job,
            round: self.tracker.round,
            priority: 0,
            cold_start: ctx.params.cold_start,
            state_load: ctx.params.state_load,
            checkpoint: ctx.params.checkpoint,
            keep_alive: false,
        });
        ctx.cluster.push_work(ctx.q, task, &items);
        ctx.cluster.request_finish(ctx.q, task);
        ctx.cluster.force_start(ctx.q, task);
        self.pool.push(task);
        self.tracker.open_tasks.push(task);
    }
}

impl Strategy for BatchedServerless {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, _est: &crate::estimator::RoundEstimate) {
        self.tracker.begin(round, ctx.q.now());
        self.buffered = 0;
        self.pool.clear();
    }

    fn on_update(&mut self, ctx: &mut Ctx, _round: u32, _party: usize, arrived: usize) {
        self.tracker.note_arrival(ctx.q.now());
        self.buffered += 1;
        let flush = arrived >= ctx.params.quorum; // end-of-round flush
        if self.buffered >= ctx.params.batch || flush {
            let n = self.buffered;
            self.buffered = 0;
            self.dispatch(ctx, n);
        }
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        match note {
            Notification::WorkItemDone { .. } => self.tracker.note_fused(),
            Notification::TaskExited { task } => {
                self.tracker.close_task(*task);
                self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
            }
            _ => {}
        }
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.tracker.completed.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::coordinator::strategies::testutil::pump;
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::EventQueue;
    use crate::workloads::Workload;

    #[test]
    fn batches_amortize_deployments() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            10,
            1,
        );
        let params = JobParams::derive(0, &spec); // batch trigger = 2
        assert_eq!(params.batch, 2);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = BatchedServerless::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
            for i in 0..10 {
                s.on_update(&mut ctx, 0, i, i + 1);
            }
        }
        let mut records = Vec::new();
        pump(&mut q, &mut cluster, &mq, &params, &mut s, &mut records);
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_work_done(0), 10, "all updates fused");
        assert_eq!(
            cluster.job_deployments(0),
            5,
            "one invocation per batch of 2"
        );
    }

    #[test]
    fn incomplete_batch_waits_until_flush() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            10,
            1,
        );
        let mut params = JobParams::derive(0, &spec);
        params.batch = 4;
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = BatchedServerless::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        let mut ctx = Ctx {
            q: &mut q,
            cluster: &mut cluster,
            mq: &mq,
            params: &params,
        };
        s.on_round_start(&mut ctx, 0, &est);
        // 3 updates < batch of 4: nothing deploys
        for i in 0..3 {
            s.on_update(&mut ctx, 0, i, i + 1);
        }
        assert_eq!(s.buffered, 3);
        assert_eq!(ctx.cluster.job_deployments(0), 0);
        // updates 4..10 trigger batches; the 10th (quorum) flushes the rest
        for i in 3..10 {
            s.on_update(&mut ctx, 0, i, i + 1);
        }
        assert_eq!(s.buffered, 0, "flush drains the buffer");
        drop(ctx);
        let mut records = Vec::new();
        pump(&mut q, &mut cluster, &mq, &params, &mut s, &mut records);
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_work_done(0), 10);
    }
}
