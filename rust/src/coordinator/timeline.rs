//! The Fig 2 scenario: one round, six parties (P1-P6) sending updates over
//! 20 s, pair-aggregation costing 1 s — rendered as a busy/idle/overhead
//! timeline per design option, exactly the illustration the paper opens §3
//! with. Also the substrate for the `timeline` integration test, which
//! pins the eager-AO utilization arithmetic the paper quotes (busy 6/21,
//! idle 71.4%).

use crate::cluster::{Cluster, ClusterConfig};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::platform::{Platform, PlatformConfig};
use crate::metrics::JobReport;
use crate::party::FleetKind;
use crate::sim::secs;
use crate::util::table::Table;
use crate::workloads::Workload;

/// A workload tuned to the Fig 2 illustration: t_pair such that one update
/// merges in 1 s on the 2-core container, negligible overheads.
pub fn fig2_workload() -> Workload {
    let mut w = Workload::cifar100_effnet();
    w.t_pair = 2.0; // 2s on one core -> 1s per update at C_agg=2
    w.cold_start_secs = 0.5;
    w.checkpoint_secs = 0.25;
    w.ancillary_cs_per_round = 0.0;
    w.base_epoch_secs = 10.0; // parties spread over ~10-20s
    w
}

/// Run the 6-party / 1-round scenario for every design option.
pub fn run_fig2(seed: u64) -> Vec<JobReport> {
    let mut spec = FlJobSpec::new(fig2_workload(), FleetKind::ActiveHeterogeneous, 6, 1);
    spec.t_wait_secs = 30.0;
    ["jit", "batched", "eager-serverless", "eager-ao", "lazy"]
        .iter()
        .map(|s| {
            let mut cfg = PlatformConfig {
                seed,
                ..Default::default()
            };
            cfg.cluster = ClusterConfig {
                capacity: 8,
                ..Default::default()
            };
            let mut p = Platform::new(cfg);
            p.admit(spec.clone(), s);
            p.run().remove(0)
        })
        .collect()
}

/// Render the comparison table the `timeline` CLI subcommand prints.
pub fn render(reports: &[JobReport]) -> String {
    let mut t = Table::new(
        "Fig 2 — aggregation design options (6 parties, 1 round)",
        &[
            "strategy",
            "agg latency (s)",
            "container-s",
            "deployments",
            "updates fused",
        ],
    );
    for r in reports {
        t.row(vec![
            r.strategy.clone(),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.2}", r.total_container_seconds()),
            format!("{}", r.deployments),
            format!("{}", r.updates_fused),
        ]);
    }
    t.render()
}

/// The paper's §3 arithmetic for eager always-on: 6 updates × 1 s of work
/// in a 21 s round → busy fraction 6/21, idle 71.4%.
pub fn eager_ao_idle_fraction(busy_secs: f64, round_secs: f64) -> f64 {
    1.0 - busy_secs / round_secs
}

/// Deterministic micro-timeline used in docs/tests: arrivals fixed at
/// uniform offsets over 20 s (the exact Fig 2 setup, bypassing fleet
/// randomness).
pub fn fixed_arrivals() -> Vec<crate::sim::Time> {
    (1..=6).map(|i| secs(i as f64 * 20.0 / 6.0)).collect()
}

/// A tiny helper the tests use to drive a one-task cluster to completion.
pub fn drain_cluster(cluster: &mut Cluster, q: &mut crate::sim::EventQueue) {
    while let Some((_, ev)) = q.next() {
        match ev {
            crate::sim::EventKind::ContainerDone { container } => {
                cluster.advance(q, container);
            }
            crate::sim::EventKind::SchedTick => cluster.on_tick(q),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_fraction_matches_paper() {
        let f = eager_ao_idle_fraction(6.0, 21.0);
        assert!((f - 0.714).abs() < 0.001, "idle fraction {f}");
    }

    #[test]
    fn fig2_ordering_holds() {
        let reports = run_fig2(7);
        assert_eq!(reports.len(), 5);
        let get = |name: &str| {
            reports
                .iter()
                .find(|r| r.strategy == name)
                .unwrap_or_else(|| panic!("{name} missing"))
        };
        let jit = get("jit");
        let lazy = get("lazy");
        let ao = get("eager-ao");
        let eager = get("eager-serverless");
        // all options fuse all six updates
        for r in &reports {
            assert_eq!(r.updates_fused, 6, "{}", r.strategy);
            assert_eq!(r.rounds.len(), 1, "{}", r.strategy);
        }
        // latency: lazy pays everything after the last update; JIT doesn't
        assert!(
            lazy.mean_latency_secs() > jit.mean_latency_secs() + 3.0,
            "lazy {} vs jit {}",
            lazy.mean_latency_secs(),
            jit.mean_latency_secs()
        );
        // cost: AO most expensive, JIT ≤ eager serverless
        assert!(ao.total_container_seconds() > eager.total_container_seconds());
        assert!(jit.total_container_seconds() <= eager.total_container_seconds());
        let render = render(&reports);
        assert!(render.contains("eager-ao"));
    }
}
