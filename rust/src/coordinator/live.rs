//! Live platform: the *same* event-driven `Strategy` implementations that
//! drive the simulator, paced by a wall clock and fed by real MQ traffic.
//!
//! The pre-driver live runtime hard-coded a two-variant `LiveStrategy`
//! enum over raw mpsc channels; it could demonstrate two of the five §3
//! aggregation designs and lost all update state when the aggregator
//! died. This module replaces it wholesale:
//!
//! * **Control plane** — one [`JobEngine`] (estimation, arrival
//!   bookkeeping, strategy dispatch) pulled by a [`WallDriver`]: the
//!   driver sleeps to the next deadline (JIT timer, container phase end,
//!   δ-tick) and wakes the moment a party publishes an update into the
//!   zero-copy MQ. All five strategies (`jit`, `batched`,
//!   `eager-serverless`, `eager-ao`, `lazy`) run here unmodified.
//! * **Data plane** — party updates are `Payload::Inline` messages in the
//!   round's MQ topic. A [`Folder`] consumes them *in offset order*,
//!   folding each into a streaming [`Aggregator`] and checkpointing the
//!   partial state (offset + accumulator) to the MQ after every fold —
//!   §5.5's "checkpointing partially aggregated model updates using the
//!   message queue". Kill the aggregator at any point and a fresh one
//!   resumes from the topic log + checkpoint to a bit-identical published
//!   model ([`run_live_on`] with `resume = true`).
//! * **Parties** — pluggable [`UpdateSource`]s: scripted publishes at the
//!   fleet model's drawn offsets on an instant clock (deterministic
//!   tests/benches, sim/live equivalence), synthetic training threads on
//!   the real wall clock, or real local training through the XLA
//!   artifacts (`PartyBackend::XlaThreads`, the end-to-end example).
//!
//! Fused global models are published one-per-round to
//! [`mq::model_topic`], which doubles as the job's durable state: a
//! restarted aggregator derives the current round and global model from
//! that log.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::cluster::{Cluster, ClusterConfig, Notification};
use crate::coordinator::driver::{
    ArrivalMode, Clock, Driver, InstantClock, JobEngine, UpdateSource, WallClock, WallDriver,
    WallTimer,
};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::platform::scenario_capacity;
use crate::fusion::{Aggregator, Algorithm};
use crate::metrics::RoundRecord;
use crate::mq::{self, CheckpointState, Message, MessageQueue, Payload};
use crate::party::FleetKind;
use crate::sim::{EventKind, EventQueue, Time};
use crate::util::rng::Rng;
use crate::workloads::Workload;

// ---------------------------------------------------------------------------
// configuration & report
// ---------------------------------------------------------------------------

/// Who plays the parties in a live run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartyBackend {
    /// Deterministic: publishes at the engine's fleet-drawn offsets on an
    /// instant clock. Used by tests, the sim/live equivalence suite and
    /// fast sweeps.
    Scripted,
    /// One OS thread per party on the real wall clock, with synthetic
    /// local training (no artifacts needed). The default for `fljit live`.
    SynthThreads,
    /// One OS thread per party running real local training through the
    /// XLA artifacts (`make artifacts` + `--features xla`).
    XlaThreads,
}

#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Any of the five §3 strategies (`strategies::by_name`).
    pub strategy: String,
    pub n_parties: usize,
    pub rounds: u32,
    pub seed: u64,
    /// Timing profile for the cluster emulation + fleet model. The MLP
    /// live profile keeps wall rounds around a second.
    pub workload: Workload,
    /// Fleet composition (active/intermittent, §6.3 axes).
    pub fleet: FleetKind,
    /// Minimum updates per round (defaults to all parties).
    pub quorum: Option<usize>,
    pub backend: PartyBackend,
    /// Update vector length for the synthetic backends.
    pub dim: usize,
    /// Synthetic local-training pull toward the party target.
    pub lr: f32,
    /// XLA backend: minibatches per epoch (2/4/8/16/32 artifacts).
    pub minibatches: usize,
    /// XLA backend: Dirichlet alpha for non-IID label skew.
    pub alpha: f64,
    /// Fault injection: abort the aggregator after this many data-plane
    /// folds, leaving the MQ intact for a resume (§5.5 test hook).
    pub kill_after_fuses: Option<u64>,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            strategy: "jit".to_string(),
            n_parties: 4,
            rounds: 5,
            seed: 42,
            workload: Workload::mlp_live(),
            fleet: FleetKind::ActiveHomogeneous,
            quorum: None,
            backend: PartyBackend::SynthThreads,
            dim: 512,
            lr: 0.3,
            minibatches: 4,
            alpha: 0.5,
            kill_after_fuses: None,
        }
    }
}

/// Per-round model quality (XLA backend only).
#[derive(Clone, Copy, Debug)]
pub struct LiveRoundStats {
    pub round: u32,
    pub train_loss: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
}

/// A live run's outcome.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub strategy: String,
    /// Strategy round records (§6.2 latency semantics, same as sim).
    pub records: Vec<RoundRecord>,
    /// Aggregation container-seconds from the emulated cluster ledger —
    /// wall seconds under the thread backends.
    pub container_seconds: f64,
    pub deployments: u64,
    /// Real data-plane folds performed by this run.
    pub updates_fused: u64,
    pub wall_secs: f64,
    /// True when `kill_after_fuses` fired: the run aborted mid-round and
    /// the MQ holds the topic log + checkpoint for a resume.
    pub crashed: bool,
    /// Set on resumed runs: the round reconstructed from the MQ.
    pub resumed_round: Option<u32>,
    /// Latest published global model (the init model if none published).
    pub final_model: Vec<f32>,
    /// XLA backend: per-round train/eval stats.
    pub stats: Vec<LiveRoundStats>,
    /// XLA backend: measured pair-fusion time on the real XLA path
    /// (§5.4 offline calibration; 0.0 for the synthetic backends).
    pub t_pair_secs: f64,
}

impl LiveReport {
    pub fn mean_latency_secs(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.latency_secs).sum::<f64>() / self.records.len() as f64
    }
}

/// Deterministic initial global model for the synthetic backends.
pub fn init_model(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x1717);
    (0..dim).map(|_| (rng.f32() - 0.5) * 0.1).collect()
}

/// Synthetic "local training": pull the global model toward a fixed
/// per-party target. Deterministic in (seed, party), so identical runs
/// publish bit-identical updates — the resume test relies on this.
pub fn synth_update(global: &[f32], seed: u64, party: usize, lr: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x5EED ^ ((party as u64) << 20));
    global
        .iter()
        .map(|&g| {
            let target = (rng.f32() - 0.5) * 2.0;
            g + lr * (target - g)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// data plane: fold-in-offset-order with per-fold checkpoints
// ---------------------------------------------------------------------------

/// Outcome of a fold pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FoldOutcome {
    Ok,
    /// The fault-injection budget ran out mid-pass.
    Killed,
}

/// The live aggregation state: a streaming weighted mean over the round
/// topic, consumed strictly in offset order. After *every* fold the
/// partial state (accumulator + consumed offset) is checkpointed to the
/// MQ, so an aggregator death at any instant loses at most nothing: the
/// next deployment reloads the checkpoint and replays the remainder of
/// the log, producing the bit-identical mean (pinned by test).
struct Folder {
    agg: Aggregator,
    consumed_to: usize,
}

impl Folder {
    fn fresh(dim: usize) -> Folder {
        Folder {
            agg: Aggregator::new(dim),
            consumed_to: 0,
        }
    }

    /// Restore from the round's MQ checkpoint slot, or start fresh.
    fn resume(mq: &MessageQueue, job: usize, round: u32, dim: usize) -> Folder {
        match mq.load_checkpoint(&mq::checkpoint_slot(job, round)) {
            Some(ck) => Folder {
                agg: Aggregator::from_parts(
                    ck.acc.unwrap_or_else(|| vec![0.0; dim]),
                    ck.weight,
                    ck.n_merged,
                ),
                consumed_to: ck.consumed_to,
            },
            None => Folder::fresh(dim),
        }
    }

    /// Fold every not-yet-consumed message in the round topic, saving a
    /// checkpoint after each fold. `budget` is the fault-injection
    /// countdown; `fused` counts this run's real folds.
    fn catch_up(
        &mut self,
        mq: &MessageQueue,
        job: usize,
        round: u32,
        now: Time,
        budget: &mut Option<u64>,
        fused: &mut u64,
    ) -> FoldOutcome {
        let topic = mq::update_topic(job, round);
        let slot = mq::checkpoint_slot(job, round);
        loop {
            let batch = mq.fetch(&topic, self.consumed_to, 64);
            if batch.is_empty() {
                return FoldOutcome::Ok;
            }
            for m in &batch {
                if let Some(b) = budget {
                    if *b == 0 {
                        return FoldOutcome::Killed;
                    }
                    *b -= 1;
                }
                if let Some(data) = m.payload.data() {
                    self.agg.add(data, m.weight);
                }
                self.consumed_to += 1;
                *fused += 1;
                mq.save_checkpoint(
                    &slot,
                    CheckpointState {
                        acc: Some(self.agg.acc.clone()),
                        weight: self.agg.weight,
                        n_merged: self.agg.n_merged,
                        consumed_to: self.consumed_to,
                        saved_at: now,
                    },
                );
            }
        }
    }

    fn finalize(&self, alg: Algorithm, prev_global: &[f32]) -> Vec<f32> {
        if self.agg.n_merged == 0 {
            return prev_global.to_vec();
        }
        self.agg.finalize(alg, Some(prev_global))
    }
}

// ---------------------------------------------------------------------------
// party sources
// ---------------------------------------------------------------------------

/// One scheduled scripted publish.
struct ScriptedPublish {
    due: Time,
    party: usize,
    round: u32,
    model: Arc<Vec<f32>>,
}

/// Deterministic parties: publish synthetic updates at exactly the
/// engine's fleet-drawn offsets. Paired with an [`InstantClock`] this
/// replays the simulator's arrival process through the real MQ path.
pub struct ScriptedParties {
    seed: u64,
    lr: f32,
    weights: Vec<f32>,
    /// Pending publishes, ascending by (due, party); drained from the
    /// front (O(1) per publish even at 10k parties).
    pending: std::collections::VecDeque<ScriptedPublish>,
}

impl ScriptedParties {
    pub fn new(seed: u64, lr: f32, weights: Vec<f32>) -> ScriptedParties {
        ScriptedParties {
            seed,
            lr,
            weights,
            pending: std::collections::VecDeque::new(),
        }
    }
}

impl UpdateSource for ScriptedParties {
    fn begin_round(
        &mut self,
        round: u32,
        model: &Arc<Vec<f32>>,
        parties: &[usize],
        offsets: &[Time],
        now: Time,
        _mq: &MessageQueue,
    ) -> Result<()> {
        for &party in parties {
            self.pending.push_back(ScriptedPublish {
                due: now + offsets[party],
                party,
                round,
                model: Arc::clone(model),
            });
        }
        // ties at the same µs publish in party order — exactly the
        // simulator's scheduling order for equal-time arrivals
        self.pending
            .make_contiguous()
            .sort_by_key(|p| (p.due, p.party));
        Ok(())
    }

    fn pump(&mut self, now: Time, mq: &MessageQueue) -> Result<()> {
        while self.pending.front().is_some_and(|p| p.due <= now) {
            let p = self.pending.pop_front().expect("front checked");
            let update = synth_update(&p.model, self.seed, p.party, self.lr);
            mq.produce(
                &mq::update_topic(0, p.round),
                Message {
                    party: p.party,
                    round: p.round,
                    weight: self.weights[p.party],
                    enqueued_at: p.due,
                    payload: Payload::Inline(update),
                },
            );
        }
        Ok(())
    }

    fn next_due(&self) -> Option<Time> {
        self.pending.front().map(|p| p.due)
    }

    fn exhausted(&self) -> bool {
        self.pending.is_empty()
    }
}

/// One message per round handed to a party thread.
struct PartyCmd {
    round: u32,
    model: Arc<Vec<f32>>,
    /// Wall deadline the synthetic party publishes at (drawn from the
    /// fleet model). XLA parties ignore it — real training sets the pace.
    due: Time,
}

/// Sets the shared failure slot if the owning thread dies without
/// disarming it — catches both `Err` returns and panics, so the driver's
/// `pump` aborts the run instead of sleeping forever on a dead party.
struct PartyFailFlag {
    failed: Arc<std::sync::Mutex<Option<String>>>,
    party: usize,
    armed: bool,
}

impl PartyFailFlag {
    fn report(&self, msg: String) {
        let mut f = self.failed.lock().unwrap();
        if f.is_none() {
            *f = Some(msg);
        }
    }
}

impl Drop for PartyFailFlag {
    fn drop(&mut self) {
        if self.armed {
            self.report(format!("party {} terminated unexpectedly", self.party));
        }
    }
}

/// Wall-clock parties: one OS thread each, publishing into the shared MQ.
pub struct ThreadParties {
    txs: Vec<mpsc::Sender<PartyCmd>>,
    handles: Vec<JoinHandle<()>>,
    /// First fatal party-side failure (error or unexpected death).
    failed: Arc<std::sync::Mutex<Option<String>>>,
    down: bool,
}

impl ThreadParties {
    /// Synthetic local training: the thread computes `synth_update` and
    /// sleeps until its drawn offset — periodic parties (§4.1) on a real
    /// clock, no artifacts required.
    pub fn synth(
        mq: &Arc<MessageQueue>,
        timer: WallTimer,
        seed: u64,
        lr: f32,
        weights: &[f32],
    ) -> ThreadParties {
        let failed = Arc::new(std::sync::Mutex::new(None));
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for (party, &weight) in weights.iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PartyCmd>();
            txs.push(tx);
            let mqc = Arc::clone(mq);
            let failedc = Arc::clone(&failed);
            handles.push(std::thread::spawn(move || {
                let mut flag = PartyFailFlag {
                    failed: failedc,
                    party,
                    armed: true,
                };
                while let Ok(cmd) = rx.recv() {
                    let update = synth_update(&cmd.model, seed, party, lr);
                    timer.sleep_until(cmd.due);
                    mqc.produce(
                        &mq::update_topic(0, cmd.round),
                        Message {
                            party,
                            round: cmd.round,
                            weight,
                            enqueued_at: timer.now(),
                            payload: Payload::Inline(update),
                        },
                    );
                }
                flag.armed = false;
            }));
        }
        ThreadParties {
            txs,
            handles,
            failed,
            down: false,
        }
    }

    /// Real local training through the XLA artifacts: each thread owns a
    /// PJRT runtime + trainer on its non-IID shard, publishes its update
    /// when the epoch actually finishes, and reports its training loss to
    /// the metrics topic.
    pub fn xla(
        mq: &Arc<MessageQueue>,
        timer: WallTimer,
        cfg: &LiveConfig,
    ) -> Result<ThreadParties> {
        use crate::party::synth_party_dataset;
        use crate::runtime::{Runtime, Trainer, MLP_CLASSES, MLP_IN};
        let dir = crate::runtime::default_artifact_dir();
        // fail fast on missing artifacts before spawning anything
        Runtime::new(&dir).context("aggregator-side artifact probe")?;
        let items = cfg.minibatches * 32;
        let failed = Arc::new(std::sync::Mutex::new(None));
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for party in 0..cfg.n_parties {
            let (tx, rx) = mpsc::channel::<PartyCmd>();
            txs.push(tx);
            let mqc = Arc::clone(mq);
            let dirc = dir.clone();
            let failedc = Arc::clone(&failed);
            let (minibatches, alpha, seed, lr) = (cfg.minibatches, cfg.alpha, cfg.seed, cfg.lr);
            handles.push(std::thread::spawn(move || {
                let mut flag = PartyFailFlag {
                    failed: failedc,
                    party,
                    armed: true,
                };
                let mut body = || -> Result<()> {
                    let rt = Runtime::new(&dirc).context("party runtime")?;
                    let (xs, ys) =
                        synth_party_dataset(party, items, MLP_IN, MLP_CLASSES, alpha, seed);
                    let mut trainer = Trainer::init(&rt, seed);
                    while let Ok(cmd) = rx.recv() {
                        trainer.unflatten(&cmd.model);
                        let loss = trainer.epoch(minibatches, &xs, &ys, lr)?;
                        mqc.produce(
                            &mq::metrics_topic(0),
                            Message {
                                party,
                                round: cmd.round,
                                weight: 1.0,
                                enqueued_at: timer.now(),
                                payload: Payload::Inline(vec![loss]),
                            },
                        );
                        mqc.produce(
                            &mq::update_topic(0, cmd.round),
                            Message {
                                party,
                                round: cmd.round,
                                weight: items as f32,
                                enqueued_at: timer.now(),
                                payload: Payload::Inline(trainer.flatten()),
                            },
                        );
                    }
                    Ok(())
                };
                if let Err(e) = body() {
                    flag.report(format!("party {party}: {e:#}"));
                }
                flag.armed = false;
            }));
        }
        Ok(ThreadParties {
            txs,
            handles,
            failed,
            down: false,
        })
    }

    fn join_all(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join(); // panics already surfaced via the fail flag
        }
    }
}

impl UpdateSource for ThreadParties {
    fn begin_round(
        &mut self,
        round: u32,
        model: &Arc<Vec<f32>>,
        parties: &[usize],
        offsets: &[Time],
        now: Time,
        _mq: &MessageQueue,
    ) -> Result<()> {
        for &party in parties {
            self.txs[party]
                .send(PartyCmd {
                    round,
                    model: Arc::clone(model),
                    due: now + offsets.get(party).copied().unwrap_or(0),
                })
                .map_err(|_| anyhow!("party {party} hung up"))?;
        }
        Ok(())
    }

    /// Threads publish on their own; a recorded party failure aborts the
    /// run here (the driver calls `pump` every iteration, so a dead party
    /// surfaces promptly instead of stalling the round forever).
    fn pump(&mut self, _now: Time, _mq: &MessageQueue) -> Result<()> {
        match self.failed.lock().unwrap().as_ref() {
            Some(msg) => Err(anyhow!("{msg}")),
            None => Ok(()),
        }
    }

    fn next_due(&self) -> Option<Time> {
        None // wall driver waits on the MQ condvar
    }

    fn exhausted(&self) -> bool {
        self.down
    }

    fn failure(&self) -> Option<String> {
        self.failed.lock().unwrap().clone()
    }

    fn shutdown(&mut self, _mq: &MessageQueue) {
        self.txs.clear(); // closes the channels; threads drain out
        self.down = true;
        self.join_all();
    }
}

// ---------------------------------------------------------------------------
// the live runner
// ---------------------------------------------------------------------------

fn live_spec(cfg: &LiveConfig) -> FlJobSpec {
    let spec = FlJobSpec::new(
        cfg.workload.clone(),
        cfg.fleet,
        cfg.n_parties,
        cfg.rounds,
    );
    match cfg.quorum {
        Some(q) => spec.with_quorum(q),
        None => spec,
    }
}

/// Run a live job on a fresh private MQ (no resume possible afterwards —
/// use [`run_live_on`] with a shared MQ for the checkpoint/resume paths).
pub fn run_live(cfg: &LiveConfig) -> Result<LiveReport> {
    run_live_on(cfg, &Arc::new(MessageQueue::new()), false)
}

/// Run a live job against an explicit MQ. With `resume = true` the runner
/// reconstructs its position from the MQ instead of starting at round 0:
/// completed rounds = the model-topic offset, the current global = the
/// last published model, and the in-progress round's partial aggregate =
/// the §5.5 checkpoint slot; the round topic's log replays into the
/// strategy as arrival events.
pub fn run_live_on(
    cfg: &LiveConfig,
    mq: &Arc<MessageQueue>,
    resume: bool,
) -> Result<LiveReport> {
    if crate::coordinator::strategies::by_name(&cfg.strategy).is_none() {
        return Err(anyhow!(
            "unknown strategy {:?}; expected one of {:?}",
            cfg.strategy,
            crate::coordinator::strategies::all_strategies()
        ));
    }
    let spec = live_spec(cfg);
    let engine = JobEngine::new(0, spec, &cfg.strategy, cfg.seed);
    let weights: Vec<f32> = engine
        .fleet
        .parties
        .iter()
        .map(|p| p.dataset_items as f32)
        .collect();
    match cfg.backend {
        PartyBackend::Scripted => {
            let source = ScriptedParties::new(cfg.seed, cfg.lr, weights);
            let driver = WallDriver::new(InstantClock::default(), source, 0);
            run_loop(cfg, mq, engine, driver, resume, init_model(cfg.dim, cfg.seed), None)
        }
        PartyBackend::SynthThreads => {
            let clock = WallClock::new();
            let source = ThreadParties::synth(mq, clock.timer, cfg.seed, cfg.lr, &weights);
            let driver = WallDriver::new(clock, source, 0);
            run_loop(cfg, mq, engine, driver, resume, init_model(cfg.dim, cfg.seed), None)
        }
        PartyBackend::XlaThreads => run_live_xla(cfg, mq, engine, resume),
    }
}

/// XLA backend: real training threads + an aggregator-side eval trainer.
fn run_live_xla(
    cfg: &LiveConfig,
    mq: &Arc<MessageQueue>,
    engine: JobEngine,
    resume: bool,
) -> Result<LiveReport> {
    use crate::party::synth_party_dataset;
    use crate::runtime::{Runtime, Trainer, XlaFusion, MLP_CLASSES, MLP_IN};
    let dir = crate::runtime::default_artifact_dir();
    let rt = Runtime::new(&dir).context("aggregator runtime")?;
    // Offline t_pair calibration on the actual XLA fusion path (§5.4).
    // The data plane itself folds through the pure-Rust kernels (bit-
    // exact resume needs deterministic folding; rust ≡ XLA ≡ pallas is
    // pinned by tests/runtime_roundtrip.rs), so this calibration is the
    // live path's XLA-aggregation exercise and its reported t_pair.
    let fusion = XlaFusion::new(&rt);
    let t_pair = {
        let spec = crate::model::zoo::mlp_default();
        let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
        let a = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let b = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let mut acc = a.data.clone();
        fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?; // warm-up/compile
        let t0 = Instant::now();
        for _ in 0..3 {
            fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?;
        }
        t0.elapsed().as_secs_f64() / 3.0
    };
    let init = Trainer::init(&rt, cfg.seed).flatten();
    let mut eval_trainer = Trainer::init(&rt, cfg.seed);
    let (eval_x, eval_y) =
        synth_party_dataset(usize::MAX - 1, 256, MLP_IN, MLP_CLASSES, 50.0, cfg.seed);
    let clock = WallClock::new();
    let source = ThreadParties::xla(mq, clock.timer, cfg)?;
    let driver = WallDriver::new(clock, source, 0);
    let mut eval = move |model: &[f32]| -> Result<(f32, f32)> {
        eval_trainer.unflatten(model);
        eval_trainer.eval(&eval_x, &eval_y)
    };
    let mut report = run_loop(cfg, mq, engine, driver, resume, init, Some(&mut eval))?;
    report.t_pair_secs = t_pair;
    Ok(report)
}

type EvalFn<'a> = &'a mut dyn FnMut(&[f32]) -> Result<(f32, f32)>;

/// The shared control loop: identical event dispatch to the simulation
/// platform, plus the real-fusion data plane and model publication.
fn run_loop<C: Clock, S: UpdateSource>(
    cfg: &LiveConfig,
    mq: &Arc<MessageQueue>,
    mut engine: JobEngine,
    mut driver: WallDriver<C, S>,
    resume: bool,
    init: Vec<f32>,
    mut eval: Option<EvalFn<'_>>,
) -> Result<LiveReport> {
    let alg = engine.spec.algorithm();
    let capacity = scenario_capacity(&engine.spec);
    let mut cluster = Cluster::new(ClusterConfig {
        capacity,
        ..Default::default()
    });
    let mut q = EventQueue::new();
    let wall_start = Instant::now();

    // resume: reconstruct position from the durable MQ state
    let dim = init.len();
    let (mut global, start_round, resumed_round) = if resume {
        let completed = mq.end_offset(&mq::model_topic(0));
        let g = if completed > 0 {
            mq.fetch(&mq::model_topic(0), completed - 1, 1)
                .first()
                .and_then(|m| m.payload.data().map(|d| d.to_vec()))
                .unwrap_or(init)
        } else {
            init
        };
        (Arc::new(g), completed as u32, Some(completed as u32))
    } else {
        (Arc::new(init), 0, None)
    };
    if start_round >= cfg.rounds {
        driver.source.shutdown(mq);
        return Ok(LiveReport {
            strategy: cfg.strategy.clone(),
            records: Vec::new(),
            container_seconds: 0.0,
            deployments: 0,
            updates_fused: 0,
            wall_secs: 0.0,
            crashed: false,
            resumed_round,
            final_model: global.as_ref().clone(),
            stats: Vec::new(),
            t_pair_secs: 0.0,
        });
    }
    engine.round = start_round;
    // Fast-forward the engine's rng stream past the completed rounds:
    // each round consumed one infos draw (inside estimate) and one
    // arrival-offsets draw, so a resumed round k draws exactly the
    // offsets the original run drew for k — re-delivered parties publish
    // on the original schedule and fold order is preserved. (Histories
    // stay empty, so the resumed round's *estimate* — and hence its
    // latency record — may differ; the published model does not, for
    // full-quorum jobs where the folded update set is the whole fleet.)
    for _ in 0..start_round {
        let _ = engine.estimate();
        let model_bytes = engine.spec.workload.model.size_bytes();
        let _ = engine
            .fleet
            .arrival_offsets(model_bytes, engine.spec.t_wait_secs, &mut engine.rng);
    }
    // (re)initialized in the RoundStart arm before any fold can happen;
    // the resume branch there reloads the §5.5 checkpoint slot
    let mut folder = Folder::fresh(dim);
    // the resumed round's updates are already in the topic log; the
    // driver replays them, so the source must not re-publish them
    let mut skip_broadcast = resumed_round;

    let mut kill = cfg.kill_after_fuses;
    let mut fused: u64 = 0;
    let mut crashed = false;
    // first unrecoverable error; party threads are still shut down
    // before it propagates
    let mut fatal: Option<anyhow::Error> = None;
    let mut stats = Vec::new();
    let mut tick_scheduled = false;

    q.schedule_at(0, EventKind::RoundStart {
        job: 0,
        round: start_round,
    });

    let mut safety: u64 = 0;
    'outer: while let Some((_, ev)) = driver.next_event(&mut q, mq) {
        safety += 1;
        debug_assert!(safety < 100_000_000, "runaway live run");
        match ev {
            EventKind::RoundStart { round, .. } => {
                if engine.done || engine.round != round {
                    continue;
                }
                driver.watch_round(round);
                folder = if resume && Some(round) == resumed_round {
                    Folder::resume(mq, 0, round, dim)
                } else {
                    Folder::fresh(dim)
                };
                let offsets =
                    engine.start_round(&mut q, &mut cluster, mq, ArrivalMode::External);
                // §5.5 resume: parties outlive the aggregator. Updates
                // already in the topic log replay from it; parties whose
                // update never landed are re-delivered the round and
                // publish as originally scheduled (same rng stream ⇒
                // same offsets ⇒ the combined log keeps the full run's
                // offset order, preserving bit-identical folding).
                let parties: Vec<usize> = if skip_broadcast.take() == Some(round) {
                    let logged: std::collections::HashSet<usize> = mq
                        .fetch(&mq::update_topic(0, round), 0, usize::MAX)
                        .iter()
                        .map(|m| m.party)
                        .collect();
                    (0..engine.spec.n_parties)
                        .filter(|p| !logged.contains(p))
                        .collect()
                } else {
                    (0..engine.spec.n_parties).collect()
                };
                if !parties.is_empty() {
                    let now = q.now();
                    if let Err(e) =
                        driver.source.begin_round(round, &global, &parties, &offsets, now, mq)
                    {
                        fatal = Some(e);
                        break 'outer;
                    }
                }
                if !tick_scheduled {
                    tick_scheduled = true;
                    q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                }
            }
            EventKind::UpdateArrival { round, party, .. } => {
                engine.handle_update(
                    &mut q,
                    &mut cluster,
                    mq,
                    round,
                    party,
                    ArrivalMode::External,
                );
            }
            EventKind::TimerAlert { round, .. } => {
                engine.on_timer(&mut q, &mut cluster, mq, round);
            }
            EventKind::ContainerDone { container } => {
                if let Some(note) = cluster.advance(&mut q, container) {
                    let fold_now = matches!(
                        note,
                        Notification::WorkItemDone { .. } | Notification::WorkDrained { .. }
                    );
                    engine.on_note(&mut q, &mut cluster, mq, &note);
                    if fold_now
                        && folder.catch_up(mq, 0, engine.round, q.now(), &mut kill, &mut fused)
                            == FoldOutcome::Killed
                    {
                        crashed = true;
                        break 'outer;
                    }
                }
            }
            EventKind::Custom { tag } => {
                engine.on_linger(&mut q, &mut cluster, mq, tag as usize);
            }
            EventKind::SchedTick => {
                cluster.on_tick(&mut q);
                tick_scheduled = false;
                if !engine.done {
                    tick_scheduled = true;
                    q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                }
            }
            _ => {}
        }
        // round completion: fold the stragglers, publish the fused model,
        // GC the round topic, advance the engine
        if let Some(rec) = engine.take_completed() {
            let round = rec.round;
            if folder.catch_up(mq, 0, round, q.now(), &mut kill, &mut fused)
                == FoldOutcome::Killed
            {
                crashed = true;
                break 'outer;
            }
            let fused_model = folder.finalize(alg, &global);
            if let Some(eval) = eval.as_mut() {
                let train_loss = mean_metric(mq, round);
                let (eval_loss, eval_acc) = match eval(&fused_model) {
                    Ok(v) => v,
                    Err(e) => {
                        fatal = Some(e);
                        break 'outer;
                    }
                };
                stats.push(LiveRoundStats {
                    round,
                    train_loss,
                    eval_loss,
                    eval_acc,
                });
            }
            mq.produce(
                &mq::model_topic(0),
                Message {
                    party: 0,
                    round,
                    weight: folder.agg.weight,
                    enqueued_at: q.now(),
                    payload: Payload::Inline(fused_model.clone()),
                },
            );
            mq.clear_checkpoint(&mq::checkpoint_slot(0, round));
            mq.drop_topic(&mq::update_topic(0, round));
            // a sub-quorum straggler may re-create the previous round's
            // topic after its drop — sweep it again one round later
            if round > 0 {
                mq.drop_topic(&mq::update_topic(0, round - 1));
            }
            global = Arc::new(fused_model);
            engine.finish_round(&mut q, &mut cluster, mq, rec);
            if engine.done {
                break;
            }
        }
    }
    let party_failure = driver.source.failure();
    driver.source.shutdown(mq);
    if engine.done {
        // final GC: straggler-recreated round topics (sub-quorum jobs).
        // A crashed run keeps everything — resume needs the logs.
        for r in 0..cfg.rounds {
            mq.drop_topic(&mq::update_topic(0, r));
        }
    }
    if let Some(e) = fatal {
        return Err(e);
    }
    if !engine.done && !crashed {
        let why = party_failure
            .map(|m| format!(": {m}"))
            .unwrap_or_default();
        return Err(anyhow!(
            "live run stalled in round {} ({} arrivals seen){why}",
            engine.round,
            engine.arrived
        ));
    }
    let now = q.now();
    Ok(LiveReport {
        strategy: cfg.strategy.clone(),
        records: engine.records.clone(),
        container_seconds: cluster.container_seconds(0, now),
        deployments: cluster.job_deployments(0),
        updates_fused: fused,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        crashed,
        resumed_round,
        final_model: global.as_ref().clone(),
        stats,
        t_pair_secs: 0.0,
    })
}

/// Mean of the round's party-reported metrics (train losses), keeping
/// only each party's *latest* report — a party re-trained after a §5.5
/// resume may have published twice for the same round.
fn mean_metric(mq: &MessageQueue, round: u32) -> f32 {
    let msgs = mq.fetch_round(&mq::metrics_topic(0), round);
    let mut latest: std::collections::BTreeMap<usize, f32> = std::collections::BTreeMap::new();
    for m in &msgs {
        if let Some(&loss) = m.payload.data().and_then(|d| d.first()) {
            latest.insert(m.party, loss);
        }
    }
    if latest.is_empty() {
        return 0.0;
    }
    latest.values().sum::<f32>() / latest.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::strategies;

    fn scripted_cfg(strategy: &str) -> LiveConfig {
        LiveConfig {
            strategy: strategy.to_string(),
            n_parties: 4,
            rounds: 2,
            seed: 11,
            backend: PartyBackend::Scripted,
            dim: 32,
            workload: Workload::mlp_live(),
            ..Default::default()
        }
    }

    #[test]
    fn all_five_strategies_run_live_scripted() {
        for name in strategies::all_strategies() {
            let cfg = scripted_cfg(name);
            let r = run_live(&cfg).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(r.records.len(), 2, "{name} rounds");
            assert_eq!(r.updates_fused, 8, "{name} folds every update once");
            assert!(!r.crashed, "{name}");
            assert_eq!(r.final_model.len(), 32, "{name}");
            assert!(r.container_seconds > 0.0, "{name}");
            assert!(r.deployments > 0, "{name}");
        }
    }

    #[test]
    fn published_model_is_the_weighted_mean_of_updates() {
        // one round, fedavg: the model topic must hold exactly the
        // weighted mean of the four synthetic updates
        let mut cfg = scripted_cfg("lazy");
        cfg.rounds = 1;
        let mq = Arc::new(MessageQueue::new());
        let r = run_live_on(&cfg, &mq, false).expect("run");
        assert_eq!(mq.end_offset(&mq::model_topic(0)), 1);

        let spec = live_spec(&cfg);
        let engine = JobEngine::new(0, spec, "lazy", cfg.seed);
        let g0 = init_model(cfg.dim, cfg.seed);
        let mut oracle = Aggregator::new(cfg.dim);
        for (party, p) in engine.fleet.parties.iter().enumerate() {
            let u = synth_update(&g0, cfg.seed, party, cfg.lr);
            oracle.add(&u, p.dataset_items as f32);
        }
        for (a, b) in r.final_model.iter().zip(oracle.acc.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn kill_mid_round_resumes_to_bit_identical_model() {
        // §5.5 acceptance: kill the live aggregator mid-round, resume a
        // fresh one from the MQ topic log + checkpoint, and the published
        // model must be bit-identical to the uninterrupted run's.
        let cfg = scripted_cfg("jit");

        let mq_full = Arc::new(MessageQueue::new());
        let full = run_live_on(&cfg, &mq_full, false).expect("uninterrupted run");
        assert!(!full.crashed);
        assert_eq!(mq_full.end_offset(&mq::model_topic(0)), 2);

        let mq_kill = Arc::new(MessageQueue::new());
        let mut cfg_kill = cfg.clone();
        cfg_kill.kill_after_fuses = Some(2);
        let dead = run_live_on(&cfg_kill, &mq_kill, false).expect("killed run");
        assert!(dead.crashed, "fault injection must trip");
        assert_eq!(dead.updates_fused, 2);
        assert_eq!(
            mq_kill.end_offset(&mq::model_topic(0)),
            0,
            "killed before publishing round 0"
        );
        // the durable state survives the crash: topic log + checkpoint
        assert!(mq_kill.end_offset(&mq::update_topic(0, 0)) > 0);
        let ck = mq_kill
            .load_checkpoint(&mq::checkpoint_slot(0, 0))
            .expect("checkpoint persisted");
        assert_eq!(ck.n_merged, 2);
        assert_eq!(ck.consumed_to, 2);

        let resumed = run_live_on(&cfg, &mq_kill, true).expect("resumed run");
        assert_eq!(resumed.resumed_round, Some(0));
        assert!(!resumed.crashed);
        assert_eq!(resumed.updates_fused, 8 - 2, "only the remainder refolds");
        assert_eq!(mq_kill.end_offset(&mq::model_topic(0)), 2);

        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            let (a, b) = (a[0].payload.data().unwrap(), b[0].payload.data().unwrap());
            assert_eq!(a, b, "round {round} model must be bit-identical");
        }
        assert_eq!(resumed.final_model, full.final_model);
    }

    #[test]
    fn kill_before_all_updates_published_still_resumes() {
        // the harder §5.5 case: eager-serverless folds per arrival, so a
        // kill after the first fold can land while later parties have not
        // yet published. Parties outlive the aggregator: on resume the
        // runner re-delivers the round to exactly the parties missing
        // from the topic log, and the combined log keeps the full run's
        // offset order — the final models stay bit-identical.
        let mut cfg = scripted_cfg("eager-serverless");
        cfg.fleet = FleetKind::ActiveHeterogeneous; // spread the arrivals

        let mq_full = Arc::new(MessageQueue::new());
        let full = run_live_on(&cfg, &mq_full, false).expect("uninterrupted run");
        assert_eq!(full.updates_fused, 8);

        let mq_kill = Arc::new(MessageQueue::new());
        let mut cfg_kill = cfg.clone();
        cfg_kill.kill_after_fuses = Some(1);
        let dead = run_live_on(&cfg_kill, &mq_kill, false).expect("killed run");
        assert!(dead.crashed);
        assert_eq!(dead.updates_fused, 1);

        let resumed = run_live_on(&cfg, &mq_kill, true).expect("resumed run");
        assert!(!resumed.crashed);
        assert_eq!(resumed.resumed_round, Some(0));
        assert_eq!(
            dead.updates_fused + resumed.updates_fused,
            8,
            "every update folds exactly once across the two incarnations"
        );
        assert_eq!(mq_kill.end_offset(&mq::model_topic(0)), 2);
        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            assert_eq!(
                a[0].payload.data().unwrap(),
                b[0].payload.data().unwrap(),
                "round {round} model must be bit-identical"
            );
        }
        assert_eq!(resumed.final_model, full.final_model);
    }

    #[test]
    fn kill_in_a_later_round_resumes_bit_identical() {
        // pins the resume rng fast-forward: a kill in round 1 must
        // re-deliver that round's missing parties at the offsets the
        // original run drew for round 1, not round 0's
        let mut cfg = scripted_cfg("eager-serverless");
        cfg.fleet = FleetKind::ActiveHeterogeneous;

        let mq_full = Arc::new(MessageQueue::new());
        let full = run_live_on(&cfg, &mq_full, false).expect("uninterrupted run");

        let mq_kill = Arc::new(MessageQueue::new());
        let mut cfg_kill = cfg.clone();
        cfg_kill.kill_after_fuses = Some(5); // round 0 folds 4; dies in round 1
        let dead = run_live_on(&cfg_kill, &mq_kill, false).expect("killed run");
        assert!(dead.crashed);
        assert_eq!(dead.updates_fused, 5);
        assert_eq!(
            mq_kill.end_offset(&mq::model_topic(0)),
            1,
            "round 0 published before the round-1 kill"
        );

        let resumed = run_live_on(&cfg, &mq_kill, true).expect("resumed run");
        assert!(!resumed.crashed);
        assert_eq!(resumed.resumed_round, Some(1));
        assert_eq!(dead.updates_fused + resumed.updates_fused, 8);
        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            assert_eq!(
                a[0].payload.data().unwrap(),
                b[0].payload.data().unwrap(),
                "round {round} model must be bit-identical"
            );
        }
        assert_eq!(resumed.final_model, full.final_model);
    }

    #[test]
    fn resume_of_a_finished_job_is_a_noop() {
        let cfg = scripted_cfg("eager-ao");
        let mq = Arc::new(MessageQueue::new());
        run_live_on(&cfg, &mq, false).expect("run");
        let r = run_live_on(&cfg, &mq, true).expect("resume");
        assert!(r.records.is_empty());
        assert_eq!(r.resumed_round, Some(2));
        assert_eq!(r.final_model.len(), cfg.dim);
    }

    #[test]
    fn synth_threads_wall_clock_smoke() {
        // real OS threads + real wall clock, scaled down to stay fast
        let mut w = Workload::mlp_live();
        w.base_epoch_secs = 0.08;
        let cfg = LiveConfig {
            strategy: "jit".to_string(),
            n_parties: 3,
            rounds: 2,
            seed: 5,
            backend: PartyBackend::SynthThreads,
            dim: 16,
            workload: w,
            ..Default::default()
        };
        let r = run_live(&cfg).expect("wall run");
        assert_eq!(r.records.len(), 2);
        assert_eq!(r.updates_fused, 6);
        assert!(r.wall_secs > 0.0);
        assert!(!r.crashed);
    }

    #[test]
    fn xla_backend_trains_or_reports_missing_artifacts() {
        let cfg = LiveConfig {
            strategy: "jit".to_string(),
            n_parties: 3,
            rounds: 2,
            minibatches: 2,
            backend: PartyBackend::XlaThreads,
            ..Default::default()
        };
        let artifacts = crate::runtime::xla_enabled()
            && crate::runtime::default_artifact_dir()
                .join("manifest.json")
                .exists();
        match run_live(&cfg) {
            Ok(r) => {
                assert!(artifacts, "must not succeed without artifacts");
                assert_eq!(r.records.len(), 2);
                assert_eq!(r.stats.len(), 2, "eval stats per round");
                assert!(r.t_pair_secs > 0.0, "§5.4 XLA t_pair calibration ran");
            }
            Err(e) => {
                assert!(!artifacts, "artifacts present but live run failed: {e:#}");
            }
        }
    }

    #[test]
    fn synth_update_is_deterministic() {
        let g = init_model(16, 3);
        let a = synth_update(&g, 9, 2, 0.3);
        let b = synth_update(&g, 9, 2, 0.3);
        assert_eq!(a, b);
        let c = synth_update(&g, 9, 3, 0.3);
        assert_ne!(a, c, "parties must differ");
    }
}
