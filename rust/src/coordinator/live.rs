//! Live platform: the *same* event-driven `Strategy` implementations that
//! drive the simulator, paced by a wall clock and fed by real MQ traffic.
//!
//! The pre-driver live runtime hard-coded a two-variant `LiveStrategy`
//! enum over raw mpsc channels; it could demonstrate two of the five §3
//! aggregation designs and lost all update state when the aggregator
//! died. This module replaces it wholesale:
//!
//! * **Control plane** — one [`JobEngine`] (estimation, arrival
//!   bookkeeping, strategy dispatch) pulled by a [`WallDriver`]: the
//!   driver sleeps to the next deadline (JIT timer, container phase end,
//!   δ-tick) and wakes the moment a party publishes an update into the
//!   zero-copy MQ. All six strategies (`jit`, `batched`,
//!   `eager-serverless`, `eager-ao`, `lazy`, `async-stale`) run here
//!   unmodified, fault injection included — the engine draws faults
//!   from the same seeded stream in every time regime.
//! * **Data plane** — party updates are `Payload::Inline` messages in the
//!   round's MQ topic. A [`Folder`] consumes them *in offset order*,
//!   folding each into a streaming [`Aggregator`] and checkpointing the
//!   partial state (offset + accumulator) to the MQ after every fold —
//!   §5.5's "checkpointing partially aggregated model updates using the
//!   message queue". Kill the aggregator at any point and a fresh one
//!   resumes from the topic log + checkpoint to a bit-identical published
//!   model (`Session::live().on(&mq).resume(true)`).
//! * **Parties** — pluggable [`UpdateSource`]s: scripted publishes at the
//!   fleet model's drawn offsets on an instant clock (deterministic
//!   tests/benches, sim/live equivalence), synthetic training threads on
//!   the real wall clock, or real local training through the XLA
//!   artifacts (`PartyBackend::XlaThreads`, the end-to-end example).
//!
//! Fused global models are published one-per-round to
//! [`mq::model_topic`], which doubles as the job's durable state: a
//! restarted aggregator derives the current round and global model from
//! that log.
//!
//! **Entry point**: construct runs through
//! [`Session`](crate::coordinator::session::Session) (`::live()` for the
//! instant clock, `::wall()` for the real one). This module houses the
//! execution machinery — party sources, the fold-and-checkpoint data
//! plane, and `session_loop`, the one multi-job control loop of which
//! a single live job is simply the N = 1 case.
//!
//! **Multi-tenancy** (§6.3 economics): `Session::live().trace(..)`
//! replays a whole job trace under the *same* wall-clock driver — jobs
//! arrive
//! at their trace times, pass the broker's admission control, share one
//! emulated cluster arbitrated by the configured
//! [`ArbitrationPolicy`](crate::broker::arbitration::ArbitrationPolicy),
//! and each keep an independent data plane (per-job round topics,
//! per-job checkpoints, per-job model topics). The driver multiplexes
//! every admitted job's update topic through one sleep/wake loop. Kill
//! the aggregator at any instant and a resume reconstructs *every* job
//! from the MQ — including jobs that were still queued for admission,
//! which are re-admitted from the persisted trace rather than dropped.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::broker::admission::{AdmissionConfig, AdmissionController};
use crate::broker::arbitration;
use crate::broker::workload::JobArrival;
use crate::cluster::{Cluster, ClusterConfig, Notification};
use crate::coordinator::driver::{
    ArrivalMode, Clock, Driver, JobEngine, UpdateSource, WallClock, WallDriver, WallTimer,
};
use crate::coordinator::session::{EventSink, JobOutcome, RunSummary, SessionEvent};
use crate::fusion::shard::{self, shard_of, ShardAccum};
use crate::fusion::{Aggregator, Algorithm};
use crate::metrics::RoundRecord;
use crate::mq::{self, CheckpointState, Message, MessageQueue, Payload};
use crate::sim::{secs, to_secs, EventKind, EventQueue, Time};
use crate::telemetry::{Registry, Scope, SpanKind};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// configuration & report
// ---------------------------------------------------------------------------

/// Who plays the parties in a live run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartyBackend {
    /// Deterministic: publishes at the engine's fleet-drawn offsets on an
    /// instant clock. Used by tests, the sim/live equivalence suite and
    /// fast sweeps.
    Scripted,
    /// One OS thread per party on the real wall clock, with synthetic
    /// local training (no artifacts needed). The default for `fljit live`.
    SynthThreads,
    /// One OS thread per party running real local training through the
    /// XLA artifacts (`make artifacts` + `--features xla`).
    XlaThreads,
}

/// Per-round model quality (XLA backend only).
#[derive(Clone, Copy, Debug)]
pub struct LiveRoundStats {
    pub round: u32,
    pub train_loss: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
}

/// Deterministic initial global model for the synthetic backends.
pub fn init_model(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x1717);
    (0..dim).map(|_| (rng.f32() - 0.5) * 0.1).collect()
}

/// Synthetic "local training": pull the global model toward a fixed
/// per-party target. Deterministic in (seed, party), so identical runs
/// publish bit-identical updates — the resume test relies on this.
pub fn synth_update(global: &[f32], seed: u64, party: usize, lr: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x5EED ^ ((party as u64) << 20));
    global
        .iter()
        .map(|&g| {
            let target = (rng.f32() - 0.5) * 2.0;
            g + lr * (target - g)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// data plane: fold-in-offset-order with per-fold checkpoints
// ---------------------------------------------------------------------------

/// Outcome of a fold pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FoldOutcome {
    Ok,
    /// The fault-injection budget ran out mid-pass.
    Killed,
}

/// Per-shard fault injection: kill L1 shard `shard` after its
/// `after_folds`-th fold this run. Siblings keep folding; the dead shard
/// is revived JIT from its own WAL checkpoint slot when the round
/// completes. `torn` emulates death *mid-checkpoint*: the fatal fold is
/// applied in memory but its checkpoint is never written, so revival
/// replays that message from the shard's topic log.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ShardKill {
    pub(crate) shard: usize,
    pub(crate) after_folds: u64,
    pub(crate) torn: bool,
}

/// One L1 aggregator shard's JIT fold state: a bucketed partial sum over
/// the shard's own topic, consumed strictly in offset order.
struct ShardFold {
    accum: ShardAccum,
    consumed_to: usize,
    /// Folds performed by this shard in this run (per-shard kill ledger).
    folds_this_run: u64,
    /// Cleared by a [`ShardKill`]; revived at round completion.
    alive: bool,
}

impl ShardFold {
    fn fresh(dim: usize) -> ShardFold {
        ShardFold {
            accum: ShardAccum::new(dim),
            consumed_to: 0,
            folds_this_run: 0,
            alive: true,
        }
    }

    fn from_checkpoint(dim: usize, ck: &CheckpointState) -> ShardFold {
        ShardFold {
            accum: ShardAccum::from_parts(
                dim,
                ck.acc.as_deref(),
                ck.weight,
                ck.n_merged,
                &ck.buckets,
            ),
            consumed_to: ck.consumed_to,
            folds_this_run: 0,
            alive: true,
        }
    }
}

/// The live aggregation data plane for one job: the L1 aggregator tree.
/// With one shard this *is* the classic single-fold plane (same topic
/// and checkpoint-slot names, one fold loop); with `n` shards each L1
/// shard folds its own topic into fixed logical buckets and the root
/// combines the partials in shard order ([`shard::root_fold`]), so the
/// published model is bit-identical for every shard count. After
/// *every* fold the folding shard checkpoints its partial state
/// (buckets + consumed offset) to its own MQ slot — §5.5's
/// "checkpointing partially aggregated model updates using the message
/// queue", per shard: kill any single shard at any instant and a fresh
/// one resumes from its slot + topic log without touching its siblings
/// (pinned by tests).
struct Folder {
    shards: Vec<ShardFold>,
    n_parties: usize,
}

impl Folder {
    fn fresh(dim: usize, n_parties: usize, shard_count: usize) -> Folder {
        Folder {
            shards: (0..shard_count.max(1)).map(|_| ShardFold::fresh(dim)).collect(),
            n_parties,
        }
    }

    /// Restore every shard from its round checkpoint slot, or fresh.
    fn resume(
        mq: &MessageQueue,
        job: usize,
        round: u32,
        dim: usize,
        n_parties: usize,
        shard_count: usize,
    ) -> Folder {
        let shard_count = shard_count.max(1);
        let shards = (0..shard_count)
            .map(|s| {
                match mq.load_checkpoint(&mq::shard_slot_for(job, round, s, shard_count)) {
                    Some(ck) => ShardFold::from_checkpoint(dim, &ck),
                    None => ShardFold::fresh(dim),
                }
            })
            .collect();
        Folder { shards, n_parties }
    }

    /// Any shard currently dead from a [`ShardKill`]?
    fn any_dead(&self) -> bool {
        self.shards.iter().any(|s| !s.alive)
    }

    /// Revive shards killed by a [`ShardKill`]: reload each dead shard
    /// from its own WAL checkpoint slot (the §5.5 per-shard resume
    /// path — in-memory state is discarded, exactly like a process
    /// death), leaving siblings untouched. The next catch-up replays
    /// the remainder of the shard's topic log.
    fn revive_dead(&mut self, mq: &MessageQueue, job: usize, round: u32, tel: &Registry) {
        let shard_count = self.shards.len();
        for s in 0..shard_count {
            if self.shards[s].alive {
                continue;
            }
            let dim = self.shards[s].accum.dim();
            self.shards[s] =
                match mq.load_checkpoint(&mq::shard_slot_for(job, round, s, shard_count)) {
                    Some(ck) => ShardFold::from_checkpoint(dim, &ck),
                    None => ShardFold::fresh(dim),
                };
            if tel.on() {
                tel.counter_add("shard_restarts_total", &Scope::job(job), 1);
            }
        }
    }

    /// Fold every not-yet-consumed message in every live shard's topic,
    /// saving the shard's checkpoint after each fold. `budget` is the
    /// whole-aggregator fault-injection countdown, `kill_shard` the
    /// per-shard one; `fused` counts this run's real folds. Folds
    /// performed by this pass are reported through `sink` as one
    /// [`SessionEvent::CheckpointWritten`], and into `tel` as a
    /// `checkpoint` span per folding shard (detail = shard id) plus a
    /// fold counter.
    #[allow(clippy::too_many_arguments)]
    fn catch_up(
        &mut self,
        mq: &MessageQueue,
        job: usize,
        round: u32,
        now: Time,
        budget: &mut Option<u64>,
        kill_shard: &mut Option<ShardKill>,
        fused: &mut u64,
        sink: &EventSink,
        tel: &Registry,
    ) -> FoldOutcome {
        let shard_count = self.shards.len();
        let n_parties = self.n_parties;
        let before = *fused;
        let mut pass_folds = vec![0u64; shard_count];
        let mut outcome = FoldOutcome::Ok;
        'shards: for s in 0..shard_count {
            if !self.shards[s].alive {
                continue;
            }
            let topic = mq::shard_topic_for(job, round, s, shard_count);
            let slot = mq::shard_slot_for(job, round, s, shard_count);
            loop {
                let batch = mq.fetch(&topic, self.shards[s].consumed_to, 64);
                if batch.is_empty() {
                    break;
                }
                for m in &batch {
                    if let Some(b) = budget {
                        if *b == 0 {
                            outcome = FoldOutcome::Killed;
                            break 'shards;
                        }
                        *b -= 1;
                    }
                    let sf = &mut self.shards[s];
                    if let Some(data) = m.payload.data() {
                        sf.accum.fold(m.party, n_parties, data, m.weight);
                    }
                    sf.consumed_to += 1;
                    sf.folds_this_run += 1;
                    *fused += 1;
                    pass_folds[s] += 1;
                    let dying = kill_shard
                        .map(|k| k.shard == s && sf.folds_this_run >= k.after_folds)
                        .unwrap_or(false);
                    let torn = dying && kill_shard.map(|k| k.torn).unwrap_or(false);
                    if !torn {
                        let (acc, weight, n_merged, buckets) = sf.accum.to_parts();
                        mq.save_checkpoint(
                            &slot,
                            CheckpointState {
                                acc,
                                weight,
                                n_merged,
                                consumed_to: sf.consumed_to,
                                saved_at: now,
                                buckets,
                            },
                        );
                    }
                    if dying {
                        sf.alive = false;
                        *kill_shard = None;
                        if tel.on() {
                            tel.counter_add("shard_kills_total", &Scope::job(job), 1);
                        }
                        continue 'shards; // siblings keep folding
                    }
                }
            }
        }
        if *fused > before {
            sink.emit(SessionEvent::CheckpointWritten {
                job,
                round,
                folds: *fused - before,
                at_secs: to_secs(now),
            });
            if tel.on() {
                for (s, &n) in pass_folds.iter().enumerate() {
                    if n > 0 {
                        tel.span_instant(SpanKind::Checkpoint, job, round, s as u64, now);
                    }
                }
                tel.counter_add("updates_folded_total", &Scope::job(job), *fused - before);
            }
        }
        outcome
    }

    /// Root fold over the shards' partials (ascending bucket order,
    /// pooled scratch) then finalize. Returns the published model and
    /// its total fused weight; an empty round (every bucket empty —
    /// including the all-parties-dropped-out shard case) re-publishes
    /// the previous global, never wedging on a zero weight.
    fn finalize(&self, alg: Algorithm, prev_global: &[f32]) -> (Vec<f32>, f32) {
        let dim = self.shards[0].accum.dim();
        let refs: Vec<&ShardAccum> = self.shards.iter().map(|sf| &sf.accum).collect();
        let agg = shard::root_fold(&refs, dim);
        if agg.n_merged == 0 {
            return (prev_global.to_vec(), agg.weight);
        }
        (agg.finalize(alg, Some(prev_global)), agg.weight)
    }
}

// ---------------------------------------------------------------------------
// party sources
// ---------------------------------------------------------------------------

/// One scheduled scripted publish.
struct ScriptedPublish {
    due: Time,
    job: usize,
    party: usize,
    round: u32,
    model: Arc<Vec<f32>>,
}

/// Per-job synth-update seed: job 0 keeps the raw seed (single-job runs
/// and their resume tests stay bit-identical), other jobs fold the job id
/// in so concurrent jobs with identical fleets train distinct models.
fn job_seed(seed: u64, job: usize) -> u64 {
    seed ^ (job as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Deterministic parties: publish synthetic updates at exactly the
/// engine's fleet-drawn offsets. Paired with an
/// [`InstantClock`](crate::coordinator::driver::InstantClock) this
/// replays the simulator's arrival process through the real MQ path —
/// for one job (`new`) or a whole broker job mix (`multi_job`).
pub struct ScriptedParties {
    seed: u64,
    lr: f32,
    /// Aggregation weights indexed `[job][party]`.
    weights: Vec<Vec<f32>>,
    /// L1 aggregator shard count: parties publish into their own shard's
    /// topic (`shards <= 1` keeps the classic flat topic names).
    shards: usize,
    /// Pending publishes, ascending by (due, job, party); drained from
    /// the front (O(1) per publish even at 10k parties).
    pending: std::collections::VecDeque<ScriptedPublish>,
}

impl ScriptedParties {
    /// Single-job parties (job id 0).
    pub fn new(seed: u64, lr: f32, weights: Vec<f32>) -> ScriptedParties {
        ScriptedParties::multi_job(seed, lr, vec![weights])
    }

    /// Multi-job parties: `weights[job][party]` per admitted job.
    pub fn multi_job(seed: u64, lr: f32, weights: Vec<Vec<f32>>) -> ScriptedParties {
        ScriptedParties {
            seed,
            lr,
            weights,
            shards: 1,
            pending: std::collections::VecDeque::new(),
        }
    }

    /// Route publishes across `n` L1 aggregator shard topics.
    pub fn with_shards(mut self, n: usize) -> ScriptedParties {
        self.shards = n.max(1);
        self
    }
}

impl UpdateSource for ScriptedParties {
    #[allow(clippy::too_many_arguments)]
    fn begin_round(
        &mut self,
        job: usize,
        round: u32,
        model: &Arc<Vec<f32>>,
        parties: &[usize],
        offsets: &[Time],
        now: Time,
        _mq: &MessageQueue,
    ) -> Result<()> {
        for &party in parties {
            self.pending.push_back(ScriptedPublish {
                due: now + offsets[party],
                job,
                party,
                round,
                model: Arc::clone(model),
            });
        }
        // ties at the same µs publish in (job, party) order — exactly the
        // simulator's scheduling order for equal-time arrivals
        self.pending
            .make_contiguous()
            .sort_by_key(|p| (p.due, p.job, p.party));
        Ok(())
    }

    fn pump(&mut self, now: Time, mq: &MessageQueue) -> Result<()> {
        while self.pending.front().is_some_and(|p| p.due <= now) {
            let p = self.pending.pop_front().expect("front checked");
            let update = synth_update(&p.model, job_seed(self.seed, p.job), p.party, self.lr);
            let n_parties = self.weights[p.job].len();
            let s = shard_of(p.party, n_parties, self.shards);
            mq.produce(
                &mq::shard_topic_for(p.job, p.round, s, self.shards),
                Message {
                    party: p.party,
                    round: p.round,
                    weight: self.weights[p.job][p.party],
                    enqueued_at: p.due,
                    payload: Payload::Inline(update),
                },
            );
        }
        Ok(())
    }

    fn next_due(&self) -> Option<Time> {
        self.pending.front().map(|p| p.due)
    }

    fn exhausted(&self) -> bool {
        self.pending.is_empty()
    }
}

/// One message per round handed to a party thread.
struct PartyCmd {
    job: usize,
    round: u32,
    model: Arc<Vec<f32>>,
    /// Wall deadline the synthetic party publishes at (drawn from the
    /// fleet model). XLA parties ignore it — real training sets the pace.
    due: Time,
}

/// Sets the shared failure slot if the owning thread dies without
/// disarming it — catches both `Err` returns and panics, so the driver's
/// `pump` aborts the run instead of sleeping forever on a dead party.
struct PartyFailFlag {
    failed: Arc<std::sync::Mutex<Option<String>>>,
    party: usize,
    armed: bool,
}

impl PartyFailFlag {
    fn report(&self, msg: String) {
        let mut f = self.failed.lock().unwrap();
        if f.is_none() {
            *f = Some(msg);
        }
    }
}

impl Drop for PartyFailFlag {
    fn drop(&mut self) {
        if self.armed {
            self.report(format!("party {} terminated unexpectedly", self.party));
        }
    }
}

/// Wall-clock parties: one OS thread each, publishing into the shared MQ.
pub struct ThreadParties {
    txs: Vec<mpsc::Sender<PartyCmd>>,
    handles: Vec<JoinHandle<()>>,
    /// First fatal party-side failure (error or unexpected death).
    failed: Arc<std::sync::Mutex<Option<String>>>,
    down: bool,
}

impl ThreadParties {
    /// Synthetic local training: the thread computes `synth_update` and
    /// sleeps until its drawn offset — periodic parties (§4.1) on a real
    /// clock, no artifacts required.
    pub fn synth(
        mq: &Arc<MessageQueue>,
        timer: WallTimer,
        seed: u64,
        lr: f32,
        weights: &[f32],
        shards: usize,
    ) -> ThreadParties {
        let failed = Arc::new(std::sync::Mutex::new(None));
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        let n_parties = weights.len();
        for (party, &weight) in weights.iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PartyCmd>();
            txs.push(tx);
            let mqc = Arc::clone(mq);
            let failedc = Arc::clone(&failed);
            let shard = shard_of(party, n_parties, shards);
            handles.push(std::thread::spawn(move || {
                let mut flag = PartyFailFlag {
                    failed: failedc,
                    party,
                    armed: true,
                };
                while let Ok(cmd) = rx.recv() {
                    let update = synth_update(&cmd.model, seed, party, lr);
                    timer.sleep_until(cmd.due);
                    mqc.produce(
                        &mq::shard_topic_for(cmd.job, cmd.round, shard, shards),
                        Message {
                            party,
                            round: cmd.round,
                            weight,
                            enqueued_at: timer.now(),
                            payload: Payload::Inline(update),
                        },
                    );
                }
                flag.armed = false;
            }));
        }
        ThreadParties {
            txs,
            handles,
            failed,
            down: false,
        }
    }

    /// Real local training through the XLA artifacts: each thread owns a
    /// PJRT runtime + trainer on its non-IID shard, publishes its update
    /// when the epoch actually finishes, and reports its training loss to
    /// the metrics topic.
    pub(crate) fn xla(
        mq: &Arc<MessageQueue>,
        timer: WallTimer,
        cfg: &XlaSessionConfig,
    ) -> Result<ThreadParties> {
        use crate::party::synth_party_dataset;
        use crate::runtime::{Runtime, Trainer, MLP_CLASSES, MLP_IN};
        let dir = crate::runtime::default_artifact_dir();
        // fail fast on missing artifacts before spawning anything
        Runtime::new(&dir).context("aggregator-side artifact probe")?;
        let items = cfg.minibatches * 32;
        let failed = Arc::new(std::sync::Mutex::new(None));
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for party in 0..cfg.n_parties {
            let (tx, rx) = mpsc::channel::<PartyCmd>();
            txs.push(tx);
            let mqc = Arc::clone(mq);
            let dirc = dir.clone();
            let failedc = Arc::clone(&failed);
            let (minibatches, alpha, seed, lr) = (cfg.minibatches, cfg.alpha, cfg.seed, cfg.lr);
            let (shard, shards) = (shard_of(party, cfg.n_parties, cfg.shards), cfg.shards);
            handles.push(std::thread::spawn(move || {
                let mut flag = PartyFailFlag {
                    failed: failedc,
                    party,
                    armed: true,
                };
                let mut body = || -> Result<()> {
                    let rt = Runtime::new(&dirc).context("party runtime")?;
                    let (xs, ys) =
                        synth_party_dataset(party, items, MLP_IN, MLP_CLASSES, alpha, seed);
                    let mut trainer = Trainer::init(&rt, seed);
                    while let Ok(cmd) = rx.recv() {
                        trainer.unflatten(&cmd.model);
                        let loss = trainer.epoch(minibatches, &xs, &ys, lr)?;
                        mqc.produce(
                            &mq::metrics_topic(cmd.job),
                            Message {
                                party,
                                round: cmd.round,
                                weight: 1.0,
                                enqueued_at: timer.now(),
                                payload: Payload::Inline(vec![loss]),
                            },
                        );
                        mqc.produce(
                            &mq::shard_topic_for(cmd.job, cmd.round, shard, shards),
                            Message {
                                party,
                                round: cmd.round,
                                weight: items as f32,
                                enqueued_at: timer.now(),
                                payload: Payload::Inline(trainer.flatten()),
                            },
                        );
                    }
                    Ok(())
                };
                if let Err(e) = body() {
                    flag.report(format!("party {party}: {e:#}"));
                }
                flag.armed = false;
            }));
        }
        Ok(ThreadParties {
            txs,
            handles,
            failed,
            down: false,
        })
    }

    fn join_all(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join(); // panics already surfaced via the fail flag
        }
    }
}

impl UpdateSource for ThreadParties {
    #[allow(clippy::too_many_arguments)]
    fn begin_round(
        &mut self,
        job: usize,
        round: u32,
        model: &Arc<Vec<f32>>,
        parties: &[usize],
        offsets: &[Time],
        now: Time,
        _mq: &MessageQueue,
    ) -> Result<()> {
        for &party in parties {
            self.txs[party]
                .send(PartyCmd {
                    job,
                    round,
                    model: Arc::clone(model),
                    due: now + offsets.get(party).copied().unwrap_or(0),
                })
                .map_err(|_| anyhow!("party {party} hung up"))?;
        }
        Ok(())
    }

    /// Threads publish on their own; a recorded party failure aborts the
    /// run here (the driver calls `pump` every iteration, so a dead party
    /// surfaces promptly instead of stalling the round forever).
    fn pump(&mut self, _now: Time, _mq: &MessageQueue) -> Result<()> {
        match self.failed.lock().unwrap().as_ref() {
            Some(msg) => Err(anyhow!("{msg}")),
            None => Ok(()),
        }
    }

    fn next_due(&self) -> Option<Time> {
        None // wall driver waits on the MQ condvar
    }

    fn exhausted(&self) -> bool {
        self.down
    }

    fn failure(&self) -> Option<String> {
        self.failed.lock().unwrap().clone()
    }

    fn shutdown(&mut self, _mq: &MessageQueue) {
        self.txs.clear(); // closes the channels; threads drain out
        self.down = true;
        self.join_all();
    }
}

// ---------------------------------------------------------------------------
// the live runner
// ---------------------------------------------------------------------------

/// XLA wall-session knobs
/// ([`Session`](crate::coordinator::session::Session) forwards these
/// from its builder).
pub(crate) struct XlaSessionConfig {
    pub(crate) n_parties: usize,
    pub(crate) minibatches: usize,
    pub(crate) alpha: f64,
    pub(crate) seed: u64,
    pub(crate) lr: f32,
    /// L1 aggregator shard count (parties route to their shard's topic).
    pub(crate) shards: usize,
}

/// XLA backend (single job): real training threads + an aggregator-side
/// eval trainer, run through the same [`session_loop`] as every other
/// session — the initial global model is overridden by the trainer's
/// flattened init, and the §5.4 t_pair calibration attaches to job 0's
/// outcome.
pub(crate) fn run_session_xla(
    mut params: LoopParams<'_>,
    mq: &Arc<MessageQueue>,
    engines: Vec<JobEngine>,
    xla: XlaSessionConfig,
) -> Result<RunSummary> {
    use crate::party::synth_party_dataset;
    use crate::runtime::{Runtime, Trainer, XlaFusion, MLP_CLASSES, MLP_IN};
    let dir = crate::runtime::default_artifact_dir();
    let rt = Runtime::new(&dir).context("aggregator runtime")?;
    // Offline t_pair calibration on the actual XLA fusion path (§5.4).
    // The data plane itself folds through the pure-Rust kernels (bit-
    // exact resume needs deterministic folding; rust ≡ XLA ≡ pallas is
    // pinned by tests/runtime_roundtrip.rs), so this calibration is the
    // live path's XLA-aggregation exercise and its reported t_pair.
    let fusion = XlaFusion::new(&rt);
    let t_pair = {
        let spec = crate::model::zoo::mlp_default();
        let mut rng = Rng::new(xla.seed ^ 0xCA11B);
        let a = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let b = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let mut acc = a.data.clone();
        fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?; // warm-up/compile
        let t0 = Instant::now();
        for _ in 0..3 {
            fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?;
        }
        t0.elapsed().as_secs_f64() / 3.0
    };
    params.init_override = Some(Trainer::init(&rt, xla.seed).flatten());
    let mut eval_trainer = Trainer::init(&rt, xla.seed);
    let (eval_x, eval_y) =
        synth_party_dataset(usize::MAX - 1, 256, MLP_IN, MLP_CLASSES, 50.0, xla.seed);
    let clock = WallClock::new();
    let source = ThreadParties::xla(mq, clock.timer, &xla)?;
    let mut eval = move |model: &[f32]| -> Result<(f32, f32)> {
        eval_trainer.unflatten(model);
        eval_trainer.eval(&eval_x, &eval_y)
    };
    let shards = xla.shards;
    let mut summary = session_loop(
        params,
        mq,
        WallDriver::new(clock, source).with_shards(shards),
        engines,
        Some(&mut eval),
    )?;
    summary.jobs[0].t_pair_secs = t_pair;
    Ok(summary)
}

pub(crate) type EvalFn<'a> = &'a mut dyn FnMut(&[f32]) -> Result<(f32, f32)>;

/// Mean of a job's round party-reported metrics (train losses), keeping
/// only each party's *latest* report — a party re-trained after a §5.5
/// resume may have published twice for the same round.
fn mean_metric(mq: &MessageQueue, job: usize, round: u32) -> f32 {
    let msgs = mq.fetch_round(&mq::metrics_topic(job), round);
    let mut latest: std::collections::BTreeMap<usize, f32> = std::collections::BTreeMap::new();
    for m in &msgs {
        if let Some(&loss) = m.payload.data().and_then(|d| d.first()) {
            latest.insert(m.party, loss);
        }
    }
    if latest.is_empty() {
        return 0.0;
    }
    latest.values().sum::<f32>() / latest.len() as f32
}

/// Per-run knobs of [`session_loop`], assembled by
/// [`Session`](crate::coordinator::session::Session).
pub(crate) struct LoopParams<'a> {
    pub(crate) arrivals: &'a [JobArrival],
    pub(crate) capacity: usize,
    pub(crate) admission: AdmissionConfig,
    pub(crate) policy: String,
    pub(crate) seed: u64,
    /// Update vector length of the synthetic data planes (`init_override`
    /// sets job 0's real dimension when present).
    pub(crate) dim: usize,
    pub(crate) kill_after_fuses: Option<u64>,
    /// L1 aggregator shard count (1 = the classic single-fold plane).
    pub(crate) shards: usize,
    /// Kill one L1 shard mid-round (fault injection; see [`ShardKill`]).
    pub(crate) kill_shard: Option<ShardKill>,
    pub(crate) resume: bool,
    /// Job 0's initial global model (XLA wall sessions: the trainer's
    /// flattened init instead of `init_model`).
    pub(crate) init_override: Option<Vec<f32>>,
    pub(crate) sink: EventSink,
    pub(crate) telemetry: Registry,
}

/// The one live control loop — every session runs through here, a
/// single job being simply the N = 1 case of the broker job mix (the
/// old separate `run_loop` is gone): the platform's event routing
/// (admission, per-job engines, shared arbitrated cluster) fused with
/// the live data plane (per-job folders, §5.5 checkpoints, model
/// publication), pulled by a wall driver that watches every admitted
/// job's topics, streaming [`SessionEvent`]s to any listener. `eval` is
/// the aggregator-side model-quality hook, applied to job 0 (the XLA
/// wall session).
pub(crate) fn session_loop<C: Clock, S: UpdateSource>(
    mut p: LoopParams<'_>,
    mq: &Arc<MessageQueue>,
    mut driver: WallDriver<C, S>,
    mut engines: Vec<JobEngine>,
    mut eval: Option<EvalFn<'_>>,
) -> Result<RunSummary> {
    let arrivals = p.arrivals;
    let n_jobs = arrivals.len();
    let resume = p.resume;
    let shards = p.shards.max(1);
    let sink = p.sink.clone();
    let tel = p.telemetry.clone();
    mq.set_telemetry(&tel);
    // jobs currently held in the admission queue — `admission_wait` span
    // pairing (begin at queue, end at release)
    let mut admission_waiting = vec![false; n_jobs];
    let policy = arbitration::by_name(&p.policy).ok_or_else(|| {
        anyhow!(
            "unknown arbitration policy {:?}; expected one of {:?}",
            p.policy,
            arbitration::all_policies()
        )
    })?;
    let mut cluster = Cluster::new(ClusterConfig {
        capacity: p.capacity.max(1),
        ..Default::default()
    });
    cluster.set_policy(policy);
    let mut ctrl = AdmissionController::new(p.admission.clone());
    let mut q = EventQueue::new();
    let wall_start = Instant::now();

    let mut globals: Vec<Arc<Vec<f32>>> = Vec::with_capacity(n_jobs);
    let mut dims: Vec<usize> = Vec::with_capacity(n_jobs);
    let mut folders: Vec<Folder> = Vec::with_capacity(n_jobs);
    let mut folded: Vec<u64> = vec![0; n_jobs];
    let mut stats: Vec<Vec<LiveRoundStats>> = vec![Vec::new(); n_jobs];
    let mut resumed_rounds: Vec<Option<u32>> = vec![None; n_jobs];
    let mut skip_broadcast: Vec<Option<u32>> = vec![None; n_jobs];
    for (job, arr) in arrivals.iter().enumerate() {
        let engine = &mut engines[job];
        let demand = arr.spec.workload.n_agg(arr.spec.n_parties) as usize;
        ctrl.register(job, demand, arr.class);
        cluster.set_job_weight(job, arr.class.weight());
        let init = if job == 0 { p.init_override.take() } else { None }
            .unwrap_or_else(|| init_model(p.dim, job_seed(p.seed, job)));
        let dim = init.len();
        // §5.5 resume, per job: completed rounds = the job's model-topic
        // offset; the current global = the last published model; queued
        // jobs (offset 0, empty topics) replay from scratch — their
        // admission happens again through the session's JobArrival events.
        let mut global = init;
        if resume {
            let completed = mq.end_offset(&mq::model_topic(job));
            if completed > 0 {
                if let Some(m) = mq.fetch(&mq::model_topic(job), completed - 1, 1).first()
                {
                    if let Some(d) = m.payload.data() {
                        global = d.to_vec();
                    }
                }
            }
            let fused = (completed as u32).min(arr.spec.rounds);
            if fused >= arr.spec.rounds {
                engine.done = true;
                resumed_rounds[job] = Some(arr.spec.rounds);
                skip_broadcast[job] = Some(arr.spec.rounds);
            } else {
                // Fast-forward the engine's rng stream past the completed
                // rounds, skip-aware: each replayed round consumes one
                // infos draw (inside estimate) and one fault/arrival
                // draw, and starved rounds are re-skipped without
                // counting as fused — so the resumed round draws exactly
                // the offsets the original run drew for it and fold
                // order is preserved.
                engine.replay_completed(fused);
                resumed_rounds[job] = Some(engine.round);
                skip_broadcast[job] = Some(engine.round);
            }
            // learned arrival distribution: reload the adaptive sketch
            // from its own checkpoint slot (written at each round
            // completion), so the resumed policy is bit-identical to the
            // uninterrupted one — the open round's arrivals replay below
            // and re-observe into the restored round sketch.
            engine.restore_adaptive(mq);
        }
        dims.push(dim);
        globals.push(Arc::new(global));
        folders.push(Folder::fresh(dim, arr.spec.n_parties, shards));
        q.schedule_at(secs(arr.at_secs), EventKind::JobArrival { job });
    }

    let mut kill = p.kill_after_fuses;
    let mut kill_shard = p.kill_shard;
    let mut crashed = false;
    let mut fatal: Option<anyhow::Error> = None;
    let mut tick_scheduled = false;
    // preemption decisions already streamed as events
    let mut preempt_seen: usize = 0;

    let mut safety: u64 = 0;
    'outer: while let Some((_, ev)) = driver.next_event(&mut q, mq) {
        safety += 1;
        debug_assert!(safety < 500_000_000, "runaway live session");
        // `touched` = the job whose strategy may have completed a round
        // in this dispatch (mirrors `Platform::poll_round_completion`).
        let touched: Option<usize> = match ev {
            EventKind::JobArrival { job } => {
                sink.emit(SessionEvent::JobSubmitted {
                    job,
                    at_secs: to_secs(q.now()),
                });
                // resume: a job whose rounds all completed before the
                // kill needs no admission (it would never release)
                if !engines[job].done {
                    let now = q.now();
                    let started = ctrl.arrive(job, now);
                    if !started.contains(&job) {
                        sink.emit(SessionEvent::JobQueued {
                            job,
                            at_secs: to_secs(now),
                        });
                        if tel.on() {
                            admission_waiting[job] = true;
                            tel.span_begin(SpanKind::AdmissionWait, job, 0, 0, now);
                            tel.counter_add("jobs_queued_total", &Scope::job(job), 1);
                        }
                    }
                    for j in started {
                        if admission_waiting[j] {
                            admission_waiting[j] = false;
                            tel.span_end(SpanKind::AdmissionWait, j, 0, 0, now);
                        }
                        sink.emit(SessionEvent::JobAdmitted {
                            job: j,
                            at_secs: to_secs(now),
                        });
                        q.schedule_at(
                            now,
                            EventKind::RoundStart {
                                job: j,
                                round: engines[j].round,
                            },
                        );
                    }
                }
                None
            }
            EventKind::RoundStart { job, round } => {
                if engines[job].done || engines[job].round != round {
                    None // stale start from a quorum-completed round
                } else {
                    let plan = engines[job].start_round(
                        &mut q,
                        &mut cluster,
                        mq,
                        ArrivalMode::External,
                    );
                    if engines[job].done {
                        // every remaining round starved below the quorum
                        // floor: the engine skipped to the end without
                        // starting anything
                        let now = q.now();
                        if sink.active() {
                            for r in round..engines[job].spec.rounds {
                                sink.emit(SessionEvent::RoundSkipped {
                                    job,
                                    round: r,
                                    at_secs: to_secs(now),
                                });
                            }
                        }
                        driver.unwatch(job);
                        sink.emit(SessionEvent::JobFinished {
                            job,
                            at_secs: to_secs(now),
                        });
                        for j in ctrl.finish(job, now) {
                            if admission_waiting[j] {
                                admission_waiting[j] = false;
                                tel.span_end(SpanKind::AdmissionWait, j, 0, 0, now);
                            }
                            sink.emit(SessionEvent::JobAdmitted {
                                job: j,
                                at_secs: to_secs(now),
                            });
                            q.schedule_at(
                                now,
                                EventKind::RoundStart {
                                    job: j,
                                    round: engines[j].round,
                                },
                            );
                        }
                        None
                    } else {
                        // the engine may have skipped starved rounds —
                        // watch and announce the round it settled on
                        let settled = engines[job].round;
                        if sink.active() {
                            for r in round..settled {
                                sink.emit(SessionEvent::RoundSkipped {
                                    job,
                                    round: r,
                                    at_secs: to_secs(q.now()),
                                });
                            }
                        }
                        let round = settled;
                        sink.emit(SessionEvent::RoundStarted {
                            job,
                            round,
                            at_secs: to_secs(q.now()),
                        });
                        tel.span_begin(SpanKind::Round, job, round, 0, q.now());
                        driver.watch_round(job, round);
                        let n_parties = engines[job].spec.n_parties;
                        folders[job] = if resume && resumed_rounds[job] == Some(round) {
                            Folder::resume(mq, job, round, dims[job], n_parties, shards)
                        } else {
                            Folder::fresh(dims[job], n_parties, shards)
                        };
                        // JIT shard spin-up: the L1 fold states exist only
                        // for the duration of the round (LIFL §3.2)
                        if shards > 1 && tel.on() {
                            tel.counter_add(
                                "shard_spinups_total",
                                &Scope::job(job),
                                shards as u64,
                            );
                        }
                        // resumed round: re-deliver only the plan's parties
                        // missing from the topic log (logged updates replay
                        // from the MQ)
                        let parties: Vec<usize> =
                            if skip_broadcast[job].take() == Some(round) {
                                let logged: std::collections::HashSet<usize> = (0..shards)
                                    .flat_map(|s| {
                                        mq.fetch(
                                            &mq::shard_topic_for(job, round, s, shards),
                                            0,
                                            usize::MAX,
                                        )
                                    })
                                    .map(|m| m.party)
                                    .collect();
                                plan.parties
                                    .iter()
                                    .copied()
                                    .filter(|p| !logged.contains(p))
                                    .collect()
                            } else {
                                plan.parties.clone()
                            };
                        let mut failed = false;
                        if !parties.is_empty() {
                            let now = q.now();
                            if let Err(e) = driver.source.begin_round(
                                job,
                                round,
                                &globals[job],
                                &parties,
                                &plan.offsets,
                                now,
                                mq,
                            ) {
                                fatal = Some(e);
                                failed = true;
                            }
                        }
                        if failed {
                            break 'outer;
                        }
                        if !tick_scheduled {
                            tick_scheduled = true;
                            q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                        }
                        None
                    }
                }
            }
            EventKind::UpdateArrival { job, round, party } => {
                engines[job].handle_update(
                    &mut q,
                    &mut cluster,
                    mq,
                    round,
                    party,
                    ArrivalMode::External,
                );
                Some(job)
            }
            EventKind::TimerAlert { job, round } => {
                engines[job].on_timer(&mut q, &mut cluster, mq, round);
                Some(job)
            }
            EventKind::ContainerDone { container } => {
                match cluster.advance(&mut q, container) {
                    Some(note) => {
                        let task = match &note {
                            Notification::Deployed { task }
                            | Notification::WorkItemDone { task }
                            | Notification::WorkDrained { task }
                            | Notification::TaskExited { task }
                            | Notification::TaskPreempted { task } => *task,
                        };
                        let job = cluster.job_of(task);
                        let fold_now = matches!(
                            note,
                            Notification::WorkItemDone { .. }
                                | Notification::WorkDrained { .. }
                        );
                        engines[job].on_note(&mut q, &mut cluster, mq, &note);
                        if fold_now
                            && folders[job].catch_up(
                                mq,
                                job,
                                engines[job].round,
                                q.now(),
                                &mut kill,
                                &mut kill_shard,
                                &mut folded[job],
                                &sink,
                                &tel,
                            ) == FoldOutcome::Killed
                        {
                            crashed = true;
                            break 'outer;
                        }
                        Some(job)
                    }
                    None => None,
                }
            }
            EventKind::Custom { tag } => {
                let task = tag as usize;
                let job = cluster.job_of(task);
                engines[job].on_linger(&mut q, &mut cluster, mq, task);
                Some(job)
            }
            EventKind::SchedTick => {
                cluster.on_tick(&mut q);
                tick_scheduled = false;
                if !engines.iter().all(|e| e.done) {
                    tick_scheduled = true;
                    q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                }
                None
            }
            EventKind::RoundTimeout { .. } => None,
        };
        // stream any preemption decisions this dispatch produced
        sink.stream_preemptions(&cluster, &mut preempt_seen);
        // round completion for the touched job: fold the stragglers,
        // publish the fused model to the job's own topic, GC, advance
        if let Some(job) = touched {
            if let Some(rec) = engines[job].take_completed() {
                let round = rec.round;
                let fuse_begin = q.now();
                // revive any shard killed mid-round: reload it JIT from
                // its own WAL checkpoint slot, siblings untouched, then
                // let the completion catch-up replay its log remainder
                folders[job].revive_dead(mq, job, round, &tel);
                if folders[job].catch_up(
                    mq,
                    job,
                    round,
                    q.now(),
                    &mut kill,
                    &mut kill_shard,
                    &mut folded[job],
                    &sink,
                    &tel,
                ) == FoldOutcome::Killed
                {
                    crashed = true;
                    break 'outer;
                }
                if folders[job].any_dead() {
                    // the per-shard kill fired during the completion pass
                    // itself: a death discards the shard's memory, so the
                    // root fold must only ever see checkpoint-restored
                    // state — revive and replay before finalizing
                    folders[job].revive_dead(mq, job, round, &tel);
                    if folders[job].catch_up(
                        mq,
                        job,
                        round,
                        q.now(),
                        &mut kill,
                        &mut kill_shard,
                        &mut folded[job],
                        &sink,
                        &tel,
                    ) == FoldOutcome::Killed
                    {
                        crashed = true;
                        break 'outer;
                    }
                }
                let (fused_model, fused_weight) =
                    folders[job].finalize(engines[job].spec.algorithm(), &globals[job]);
                tel.span_begin(SpanKind::Fuse, job, round, 0, fuse_begin);
                tel.span_end(SpanKind::Fuse, job, round, 0, q.now());
                // aggregator-side model-quality hook (XLA wall sessions)
                if job == 0 {
                    if let Some(eval) = eval.as_mut() {
                        let train_loss = mean_metric(mq, job, round);
                        let mut failed = false;
                        match eval(&fused_model) {
                            Ok((eval_loss, eval_acc)) => stats[job].push(LiveRoundStats {
                                round,
                                train_loss,
                                eval_loss,
                                eval_acc,
                            }),
                            Err(e) => {
                                fatal = Some(e);
                                failed = true;
                            }
                        }
                        if failed {
                            break 'outer;
                        }
                    }
                }
                mq.produce(
                    &mq::model_topic(job),
                    Message {
                        party: 0,
                        round,
                        weight: fused_weight,
                        enqueued_at: q.now(),
                        payload: Payload::Inline(fused_model.clone()),
                    },
                );
                sink.emit(SessionEvent::RoundFused {
                    job,
                    round,
                    latency_secs: rec.latency_secs,
                    at_secs: to_secs(q.now()),
                });
                tel.span_end(SpanKind::Round, job, round, 0, q.now());
                // release the round's shards JIT: checkpoints cleared,
                // topics dropped (this round now, the previous one for
                // straggler-recreated topics)
                for s in 0..shards {
                    mq.clear_checkpoint(&mq::shard_slot_for(job, round, s, shards));
                    mq.drop_topic(&mq::shard_topic_for(job, round, s, shards));
                    if round > 0 {
                        mq.drop_topic(&mq::shard_topic_for(job, round - 1, s, shards));
                    }
                }
                globals[job] = Arc::new(fused_model);
                let now = q.now();
                let finished = engines[job].finish_round(&mut q, &mut cluster, mq, rec);
                if finished {
                    driver.unwatch(job);
                    sink.emit(SessionEvent::JobFinished {
                        job,
                        at_secs: to_secs(now),
                    });
                    // freed admission demand releases queued jobs
                    // (backpressure)
                    for j in ctrl.finish(job, now) {
                        if admission_waiting[j] {
                            admission_waiting[j] = false;
                            tel.span_end(SpanKind::AdmissionWait, j, 0, 0, now);
                        }
                        sink.emit(SessionEvent::JobAdmitted {
                            job: j,
                            at_secs: to_secs(now),
                        });
                        q.schedule_at(
                            now,
                            EventKind::RoundStart {
                                job: j,
                                round: engines[j].round,
                            },
                        );
                    }
                }
            }
        }
        // Thread-backed sources never report "exhausted" while their
        // parties live, so once every engine is done and no event or
        // scripted publish remains there is nothing left to drive —
        // break instead of idling on the MQ condvar. (With pending
        // scripted straggler publishes the loop keeps draining them,
        // exactly like the virtual-time platform drains its
        // pre-scheduled arrivals, so sim/live spans stay bit-identical.)
        if q.is_empty()
            && driver.source.next_due().is_none()
            && engines.iter().all(|e| e.done)
        {
            break;
        }
    }

    let party_failure = driver.source.failure();
    driver.source.shutdown(mq);
    // decisions made by the loop's final dispatch: the crash/fatal
    // breaks exit before the in-loop streaming call, so flush here
    sink.stream_preemptions(&cluster, &mut preempt_seen);
    if crashed {
        sink.emit(SessionEvent::Crashed {
            at_secs: to_secs(q.now()),
        });
    }
    let all_done = engines.iter().all(|e| e.done);
    if all_done {
        // final GC: straggler-recreated round topics. A crashed run keeps
        // everything — resume needs the logs.
        for (job, e) in engines.iter().enumerate() {
            for r in 0..e.spec.rounds {
                for s in 0..shards {
                    mq.drop_topic(&mq::shard_topic_for(job, r, s, shards));
                }
            }
        }
    }
    if let Some(e) = fatal {
        return Err(e);
    }
    if !all_done && !crashed {
        let stuck: Vec<String> = engines
            .iter()
            .filter(|e| !e.done)
            .map(|e| format!("job {} in round {}", e.params.job, e.round))
            .collect();
        let why = party_failure.map(|m| format!(": {m}")).unwrap_or_default();
        return Err(anyhow!(
            "live session stalled ({}){why}",
            stuck.join(", ")
        ));
    }
    let now = q.now();
    if tel.on() {
        // deploy/preempt spans come off the cluster's own records, so
        // recording them post-loop perturbs nothing and misses nothing
        for d in cluster.ledger() {
            tel.span_begin(SpanKind::Deploy, d.job, 0, d.task as u64, d.start);
            tel.span_end(SpanKind::Deploy, d.job, 0, d.task as u64, d.end.unwrap_or(now));
            tel.counter_add("deployments_total", &Scope::job(d.job), 1);
        }
        for &(t, task) in cluster.preemption_log() {
            let job = cluster.job_of(task);
            tel.span_instant(SpanKind::Preempt, job, 0, task as u64, t);
            tel.counter_add("preemptions_total", &Scope::job(job), 1);
        }
        tel.flush();
    }
    let span = to_secs(now);
    let total_cs = cluster.total_container_seconds(now);
    let jobs: Vec<JobOutcome> = arrivals
        .iter()
        .enumerate()
        .map(|(job, arr)| JobOutcome {
            job,
            name: arr.spec.name.clone(),
            strategy: arr.strategy.clone(),
            workload: arr.spec.workload.name.to_string(),
            fleet: arr.spec.fleet_kind.name().to_string(),
            class: arr.class,
            parties: arr.spec.n_parties,
            arrival_secs: arr.at_secs,
            queue_wait_secs: ctrl.queue_wait_secs(job),
            records: engines[job].records.clone(),
            container_seconds: cluster.container_seconds(job, now),
            ancillary_seconds: arr.spec.workload.ancillary_cs_per_round
                * engines[job].records.len() as f64,
            deployments: cluster.job_deployments(job),
            updates_fused: cluster.job_work_done(job),
            updates_folded: folded[job],
            makespan_secs: to_secs(engines[job].finished_at),
            final_model: globals[job].as_ref().clone(),
            resumed_round: resumed_rounds[job],
            stats: std::mem::take(&mut stats[job]),
            t_pair_secs: 0.0,
            solo_mean_latency_secs: None,
            updates_dropped: engines[job].updates_dropped,
            updates_decayed: engines[job].updates_decayed,
            rounds_skipped: engines[job].rounds_skipped,
        })
        .collect();
    Ok(RunSummary {
        policy: p.policy.clone(),
        capacity: p.capacity.max(1),
        seed: p.seed,
        jobs,
        cluster_utilization: total_cs / (p.capacity.max(1) as f64 * span.max(1e-9)),
        total_container_seconds: total_cs,
        span_secs: span,
        updates_folded: folded.iter().sum(),
        preemptions: cluster
            .preemption_log()
            .iter()
            .map(|&(t, task)| (to_secs(t), task))
            .collect(),
        wall_secs: wall_start.elapsed().as_secs_f64(),
        crashed,
    })
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::workload::JobTrace;
    use crate::broker::SloClass;
    use crate::coordinator::job::FlJobSpec;
    use crate::coordinator::session::{JobHandle, Report, Session};
    use crate::coordinator::strategies;
    use crate::party::FleetKind;
    use crate::workloads::Workload;

    fn scripted_spec(parties: usize, rounds: u32) -> FlJobSpec {
        FlJobSpec::new(
            Workload::mlp_live(),
            FleetKind::ActiveHomogeneous,
            parties,
            rounds,
        )
    }

    /// The standard single-job live session of the old unit tests:
    /// 4 parties × 2 rounds, dim 32, seed 11, scripted instant clock.
    fn live_session(strategy: &str) -> (Session, JobHandle) {
        let mut s = Session::live().seed(11).dim(32);
        let h = s.job(scripted_spec(4, 2), strategy);
        (s, h)
    }

    #[test]
    fn all_six_strategies_run_live_scripted() {
        for name in strategies::all_strategies() {
            let (s, h) = live_session(name);
            let r = s.run().unwrap_or_else(|e| panic!("{name}: {e:#}"));
            let o = r.job(h);
            assert_eq!(o.records.len(), 2, "{name} rounds");
            assert_eq!(o.updates_folded, 8, "{name} folds every update once");
            assert!(!r.summary().crashed, "{name}");
            assert_eq!(o.final_model.len(), 32, "{name}");
            assert!(o.container_seconds > 0.0, "{name}");
            assert!(o.deployments > 0, "{name}");
        }
    }

    #[test]
    fn published_model_is_the_weighted_mean_of_updates() {
        // one round, fedavg: the model topic must hold exactly the
        // weighted mean of the four synthetic updates
        let (seed, dim, lr) = (11u64, 32usize, 0.3f32);
        let mq = Arc::new(MessageQueue::new());
        let mut s = Session::live().seed(seed).dim(dim).lr(lr).on(&mq);
        let h = s.job(scripted_spec(4, 1), "lazy");
        let r = s.run().expect("run");
        assert_eq!(mq.end_offset(&mq::model_topic(0)), 1);

        let engine = JobEngine::new(0, scripted_spec(4, 1), "lazy", seed);
        let g0 = init_model(dim, seed);
        let mut oracle = Aggregator::new(dim);
        for (party, p) in engine.fleet.parties.iter().enumerate() {
            let u = synth_update(&g0, seed, party, lr);
            oracle.add(&u, p.dataset_items as f32);
        }
        for (a, b) in r.job(h).final_model.iter().zip(oracle.acc.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    /// Build the kill/resume triple for one strategy + fleet: an
    /// uninterrupted run, a killed run on a fresh MQ, and a resumed run
    /// on the killed MQ — all through the `Session` façade.
    fn kill_resume_session(
        strategy: &str,
        fleet: FleetKind,
        mq: &Arc<MessageQueue>,
        kill: Option<u64>,
        resume: bool,
    ) -> (Report, JobHandle) {
        let mut s = Session::live()
            .seed(11)
            .dim(32)
            .on(mq)
            .kill_after_fuses(kill)
            .resume(resume);
        let h = s.job(
            FlJobSpec::new(Workload::mlp_live(), fleet, 4, 2),
            strategy,
        );
        (s.run().expect("session run"), h)
    }

    #[test]
    fn kill_mid_round_resumes_to_bit_identical_model() {
        // §5.5 acceptance: kill the live aggregator mid-round, resume a
        // fresh one from the MQ topic log + checkpoint, and the published
        // model must be bit-identical to the uninterrupted run's.
        let fleet = FleetKind::ActiveHomogeneous;
        let mq_full = Arc::new(MessageQueue::new());
        let (full, hf) = kill_resume_session("jit", fleet, &mq_full, None, false);
        assert!(!full.summary().crashed);
        assert_eq!(mq_full.end_offset(&mq::model_topic(0)), 2);

        let mq_kill = Arc::new(MessageQueue::new());
        let (dead, hd) = kill_resume_session("jit", fleet, &mq_kill, Some(2), false);
        assert!(dead.summary().crashed, "fault injection must trip");
        assert_eq!(dead.job(hd).updates_folded, 2);
        assert_eq!(
            mq_kill.end_offset(&mq::model_topic(0)),
            0,
            "killed before publishing round 0"
        );
        // the durable state survives the crash: topic log + checkpoint
        assert!(mq_kill.end_offset(&mq::update_topic(0, 0)) > 0);
        let ck = mq_kill
            .load_checkpoint(&mq::checkpoint_slot(0, 0))
            .expect("checkpoint persisted");
        assert_eq!(ck.n_merged, 2);
        assert_eq!(ck.consumed_to, 2);

        let (resumed, hr) = kill_resume_session("jit", fleet, &mq_kill, None, true);
        assert_eq!(resumed.job(hr).resumed_round, Some(0));
        assert!(!resumed.summary().crashed);
        assert_eq!(
            resumed.job(hr).updates_folded,
            8 - 2,
            "only the remainder refolds"
        );
        assert_eq!(mq_kill.end_offset(&mq::model_topic(0)), 2);

        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            let (a, b) = (a[0].payload.data().unwrap(), b[0].payload.data().unwrap());
            assert_eq!(a, b, "round {round} model must be bit-identical");
        }
        assert_eq!(resumed.job(hr).final_model, full.job(hf).final_model);
    }

    #[test]
    fn kill_before_all_updates_published_still_resumes() {
        // the harder §5.5 case: eager-serverless folds per arrival, so a
        // kill after the first fold can land while later parties have not
        // yet published. Parties outlive the aggregator: on resume the
        // runner re-delivers the round to exactly the parties missing
        // from the topic log, and the combined log keeps the full run's
        // offset order — the final models stay bit-identical.
        let fleet = FleetKind::ActiveHeterogeneous; // spread the arrivals
        let mq_full = Arc::new(MessageQueue::new());
        let (full, hf) =
            kill_resume_session("eager-serverless", fleet, &mq_full, None, false);
        assert_eq!(full.job(hf).updates_folded, 8);

        let mq_kill = Arc::new(MessageQueue::new());
        let (dead, hd) =
            kill_resume_session("eager-serverless", fleet, &mq_kill, Some(1), false);
        assert!(dead.summary().crashed);
        assert_eq!(dead.job(hd).updates_folded, 1);

        let (resumed, hr) =
            kill_resume_session("eager-serverless", fleet, &mq_kill, None, true);
        assert!(!resumed.summary().crashed);
        assert_eq!(resumed.job(hr).resumed_round, Some(0));
        assert_eq!(
            dead.job(hd).updates_folded + resumed.job(hr).updates_folded,
            8,
            "every update folds exactly once across the two incarnations"
        );
        assert_eq!(mq_kill.end_offset(&mq::model_topic(0)), 2);
        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            assert_eq!(
                a[0].payload.data().unwrap(),
                b[0].payload.data().unwrap(),
                "round {round} model must be bit-identical"
            );
        }
        assert_eq!(resumed.job(hr).final_model, full.job(hf).final_model);
    }

    #[test]
    fn kill_in_a_later_round_resumes_bit_identical() {
        // pins the resume rng fast-forward: a kill in round 1 must
        // re-deliver that round's missing parties at the offsets the
        // original run drew for round 1, not round 0's
        let fleet = FleetKind::ActiveHeterogeneous;
        let mq_full = Arc::new(MessageQueue::new());
        let (full, hf) =
            kill_resume_session("eager-serverless", fleet, &mq_full, None, false);

        let mq_kill = Arc::new(MessageQueue::new());
        // round 0 folds 4; dies in round 1
        let (dead, hd) =
            kill_resume_session("eager-serverless", fleet, &mq_kill, Some(5), false);
        assert!(dead.summary().crashed);
        assert_eq!(dead.job(hd).updates_folded, 5);
        assert_eq!(
            mq_kill.end_offset(&mq::model_topic(0)),
            1,
            "round 0 published before the round-1 kill"
        );

        let (resumed, hr) =
            kill_resume_session("eager-serverless", fleet, &mq_kill, None, true);
        assert!(!resumed.summary().crashed);
        assert_eq!(resumed.job(hr).resumed_round, Some(1));
        assert_eq!(
            dead.job(hd).updates_folded + resumed.job(hr).updates_folded,
            8
        );
        for round in 0..2u32 {
            let a = mq_full.fetch(&mq::model_topic(0), round as usize, 1);
            let b = mq_kill.fetch(&mq::model_topic(0), round as usize, 1);
            assert_eq!(
                a[0].payload.data().unwrap(),
                b[0].payload.data().unwrap(),
                "round {round} model must be bit-identical"
            );
        }
        assert_eq!(resumed.job(hr).final_model, full.job(hf).final_model);
    }

    #[test]
    fn resume_of_a_finished_job_is_a_noop() {
        let mq = Arc::new(MessageQueue::new());
        let mut s = Session::live().seed(11).dim(32).on(&mq);
        s.job(scripted_spec(4, 2), "eager-ao");
        s.run().expect("run");
        let mut s = Session::live().seed(11).dim(32).on(&mq).resume(true);
        let h = s.job(scripted_spec(4, 2), "eager-ao");
        let r = s.run().expect("resume");
        assert!(r.job(h).records.is_empty());
        assert_eq!(r.job(h).resumed_round, Some(2));
        assert_eq!(r.job(h).final_model.len(), 32);
        assert_eq!(r.job(h).updates_folded, 0, "nothing refolds");
    }

    #[test]
    fn synth_threads_wall_clock_smoke() {
        // real OS threads + real wall clock, scaled down to stay fast
        let mut w = Workload::mlp_live();
        w.base_epoch_secs = 0.08;
        let mut s = Session::wall().seed(5).dim(16);
        let h = s.job(
            FlJobSpec::new(w, FleetKind::ActiveHomogeneous, 3, 2),
            "jit",
        );
        let r = s.run().expect("wall run");
        assert_eq!(r.mode_name(), "wall");
        assert_eq!(r.job(h).records.len(), 2);
        assert_eq!(r.job(h).updates_folded, 6);
        assert!(r.summary().wall_secs > 0.0);
        assert!(!r.summary().crashed);
    }

    #[test]
    fn xla_backend_trains_or_reports_missing_artifacts() {
        let mut s = Session::wall()
            .backend(PartyBackend::XlaThreads)
            .minibatches(2)
            .seed(42);
        let h = s.job(scripted_spec(3, 2), "jit");
        let artifacts = crate::runtime::xla_enabled()
            && crate::runtime::default_artifact_dir()
                .join("manifest.json")
                .exists();
        match s.run() {
            Ok(r) => {
                assert!(artifacts, "must not succeed without artifacts");
                assert_eq!(r.job(h).records.len(), 2);
                assert_eq!(r.job(h).stats.len(), 2, "eval stats per round");
                assert!(
                    r.job(h).t_pair_secs > 0.0,
                    "§5.4 XLA t_pair calibration ran"
                );
            }
            Err(e) => {
                assert!(!artifacts, "artifacts present but live run failed: {e:#}");
            }
        }
    }

    #[test]
    fn synth_update_is_deterministic() {
        let g = init_model(16, 3);
        let a = synth_update(&g, 9, 2, 0.3);
        let b = synth_update(&g, 9, 2, 0.3);
        assert_eq!(a, b);
        let c = synth_update(&g, 9, 3, 0.3);
        assert_ne!(a, c, "parties must differ");
    }

    // -----------------------------------------------------------------
    // live multi-tenancy
    // -----------------------------------------------------------------

    fn arrival(i: usize, at: f64, parties: usize, strategy: &str, class: SloClass) -> JobArrival {
        let mut spec = FlJobSpec::new(
            Workload::mlp_live(),
            FleetKind::ActiveHomogeneous,
            parties,
            2,
        );
        spec.name = format!("t{i}");
        JobArrival {
            at_secs: at,
            spec,
            strategy: strategy.to_string(),
            class,
        }
    }

    fn two_job_trace() -> JobTrace {
        JobTrace::from_arrivals(vec![
            arrival(0, 0.0, 3, "jit", SloClass::Standard),
            arrival(1, 0.5, 4, "jit", SloClass::Premium),
        ])
    }

    /// The standard multi-job live session of the old broker tests.
    fn broker_session(trace: &JobTrace, policy: &str) -> Session {
        Session::live()
            .trace(trace)
            .policy(policy)
            .capacity(8)
            .seed(0x11FE)
            .dim(24)
    }

    #[test]
    fn live_broker_runs_concurrent_jobs_with_independent_data_planes() {
        let trace = two_job_trace();
        let mq = Arc::new(MessageQueue::new());
        let rep = broker_session(&trace, "deadline")
            .on(&mq)
            .run()
            .expect("live broker run");
        let sum = rep.summary();
        assert_eq!(sum.jobs.len(), 2);
        assert!(!sum.crashed);
        for (job, o) in sum.jobs.iter().enumerate() {
            assert_eq!(o.records.len(), 2, "job {job} rounds");
            assert_eq!(o.final_model.len(), 24, "job {job} model");
            assert!(o.container_seconds > 0.0, "job {job} busy");
            assert!(o.deployments > 0, "job {job} deployments");
            assert_eq!(
                mq.end_offset(&mq::model_topic(job)),
                2,
                "job {job} publishes one model per round to its own topic"
            );
        }
        // every update folded exactly once: 3·2 + 4·2
        assert_eq!(sum.updates_folded, 14);
        assert!(
            sum.max_concurrent_jobs() >= 2,
            "jobs 0.5s apart with multi-second spans must overlap"
        );
        // the two jobs train different models (per-job synth seeds)
        assert_ne!(sum.jobs[0].final_model, sum.jobs[1].final_model);
        assert!(sum.cluster_utilization > 0.0);
        assert!(sum.span_secs > 0.0);
    }

    /// Contended trace: an always-on job hogs the single container, so a
    /// JIT job's FORCE_TRIGGER *must* preempt — exercising the
    /// policy-driven victim selection on every policy.
    fn contended_trace() -> JobTrace {
        JobTrace::from_arrivals(vec![
            arrival(0, 0.0, 3, "eager-ao", SloClass::BestEffort),
            arrival(1, 0.2, 3, "jit", SloClass::Premium),
        ])
    }

    #[test]
    fn live_broker_preemption_is_deterministic_per_policy_and_starves_nobody() {
        for policy in arbitration::all_policies() {
            let trace = contended_trace();
            // one slot: preemption is the only way in
            let a = broker_session(&trace, policy)
                .capacity(1)
                .run()
                .unwrap_or_else(|e| panic!("{policy}: {e:#}"));
            let b = broker_session(&trace, policy)
                .capacity(1)
                .run()
                .unwrap_or_else(|e| panic!("{policy} rerun: {e:#}"));
            let (a, b) = (a.summary(), b.summary());
            // no-starvation: every job finishes all rounds under every
            // policy even when preemption is the only path to capacity
            for o in &a.jobs {
                assert_eq!(o.records.len(), 2, "{policy}: job {} starved", o.job);
            }
            assert!(
                !a.preemptions.is_empty(),
                "{policy}: the contended trace must preempt at least once"
            );
            // policy determinism: same seed + trace ⇒ bit-identical
            // preemption order and round records
            assert_eq!(a.preemptions, b.preemptions, "{policy}: preemption order");
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.records.len(), y.records.len());
                for (r, s) in x.records.iter().zip(&y.records) {
                    assert_eq!(r.latency_secs.to_bits(), s.latency_secs.to_bits());
                    assert_eq!(r.complete_secs.to_bits(), s.complete_secs.to_bits());
                }
                assert_eq!(x.final_model, y.final_model, "{policy}: model bits");
            }
        }
    }

    #[test]
    fn live_broker_kill_resumes_running_and_queued_jobs() {
        // Three jobs, single-admission budget: job 0 runs while jobs 1–2
        // queue. Kill the aggregator mid-fold of job 0's first round —
        // jobs 1–2 have NO MQ state at that instant. Resume must (a)
        // rebuild job 0 from its topic log + checkpoint to bit-identical
        // models and (b) re-admit the queued jobs from the trace instead
        // of dropping them.
        let trace = JobTrace::from_arrivals(vec![
            arrival(0, 0.0, 3, "jit", SloClass::Standard),
            arrival(1, 0.3, 3, "jit", SloClass::Standard),
            arrival(2, 0.6, 4, "jit", SloClass::BestEffort),
        ]);
        let admission = AdmissionConfig {
            budget: 64,
            max_jobs: 1,
            autoscale: None,
        };

        let mq_full = Arc::new(MessageQueue::new());
        let full = broker_session(&trace, "deadline")
            .admission(admission.clone())
            .on(&mq_full)
            .run()
            .expect("uninterrupted");
        let full = full.summary();
        assert!(!full.crashed);
        assert!(
            full.jobs[1].queue_wait_secs > 0.0 && full.jobs[2].queue_wait_secs > 0.0,
            "max_jobs 1 must serialize admission"
        );

        let mq_kill = Arc::new(MessageQueue::new());
        let dead = broker_session(&trace, "deadline")
            .admission(admission.clone())
            .kill_after_fuses(Some(2))
            .on(&mq_kill)
            .run()
            .expect("killed");
        let dead = dead.summary();
        assert!(dead.crashed, "fault injection must trip");
        assert_eq!(dead.updates_folded, 2);
        assert_eq!(
            mq_kill.end_offset(&mq::model_topic(0)),
            0,
            "job 0 died before publishing round 0"
        );
        for job in 1..3 {
            assert!(
                dead.jobs[job].records.is_empty(),
                "job {job} must still be queued at the kill"
            );
            assert_eq!(mq_kill.end_offset(&mq::model_topic(job)), 0);
        }

        let resumed = broker_session(&trace, "deadline")
            .admission(admission)
            .on(&mq_kill)
            .resume(true)
            .run()
            .expect("resumed");
        let resumed = resumed.summary();
        assert!(!resumed.crashed);
        assert_eq!(resumed.jobs[0].resumed_round, Some(0));
        for job in 0..3 {
            assert_eq!(
                mq_kill.end_offset(&mq::model_topic(job)),
                2,
                "job {job} must complete all rounds after resume (queued \
                 jobs re-admitted from the trace)"
            );
            for round in 0..2usize {
                let a = mq_full.fetch(&mq::model_topic(job), round, 1);
                let b = mq_kill.fetch(&mq::model_topic(job), round, 1);
                assert_eq!(
                    a[0].payload.data().unwrap(),
                    b[0].payload.data().unwrap(),
                    "job {job} round {round} model must be bit-identical"
                );
            }
            assert_eq!(resumed.jobs[job].final_model, full.jobs[job].final_model);
        }
        assert_eq!(
            dead.updates_folded + resumed.updates_folded,
            full.updates_folded,
            "every update folds exactly once across the two incarnations"
        );
    }

    #[test]
    fn live_broker_resume_of_a_finished_run_is_a_noop() {
        let trace = two_job_trace();
        let mq = Arc::new(MessageQueue::new());
        broker_session(&trace, "wfs").on(&mq).run().expect("run");
        let r = broker_session(&trace, "wfs")
            .on(&mq)
            .resume(true)
            .run()
            .expect("resume");
        let r = r.summary();
        assert!(!r.crashed);
        assert_eq!(r.updates_folded, 0, "nothing refolds");
        for (job, o) in r.jobs.iter().enumerate() {
            assert!(o.records.is_empty());
            assert_eq!(o.resumed_round, Some(2));
            assert_eq!(mq.end_offset(&mq::model_topic(job)), 2, "job {job}");
        }
    }

    #[test]
    fn live_broker_rejects_bad_inputs() {
        let trace = two_job_trace();
        assert!(broker_session(&trace, "bogus").run().is_err());
        let empty = JobTrace::default();
        assert!(
            broker_session(&empty, "deadline").run().is_err(),
            "empty trace = session with no jobs"
        );
    }

    #[test]
    fn live_broker_wall_clock_smoke() {
        // real wall pacing, scaled down to stay fast
        let mut trace = two_job_trace();
        for a in &mut trace.arrivals {
            a.spec.workload.base_epoch_secs = 0.08;
            a.spec.rounds = 1;
        }
        trace.arrivals[1].at_secs = 0.1;
        let rep = Session::wall()
            .trace(&trace)
            .policy("least-slack")
            .capacity(8)
            .seed(0x11FE)
            .dim(24)
            .run()
            .expect("wall run");
        assert_eq!(rep.mode_name(), "wall");
        let sum = rep.summary();
        assert!(!sum.crashed);
        assert!(sum.wall_secs > 0.0);
        for o in &sum.jobs {
            assert_eq!(o.records.len(), 1);
        }
    }
}
