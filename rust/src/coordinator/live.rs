//! The live platform: wall-clock federated training with **real** local
//! training (L2 `train_epoch` artifacts) and **real** XLA aggregation (the
//! L1 Pallas-kernel artifacts), scheduled by the same JIT policy as the
//! simulator. Python never runs here — only the AOT artifacts.
//!
//! Shape of a round (JIT mode):
//! 1. broadcast the global model to every party thread;
//! 2. parties run one local epoch each (`runtime::Trainer::epoch`) on
//!    their non-IID shard and send (update, weight, measured epoch time);
//! 3. the aggregator *sleeps* until `t_rnd − t_agg` — `t_rnd` predicted
//!    from each party's previously-measured epoch times (periodicity,
//!    §4.1), `t_agg` from the offline `t_pair` calibration (§5.4);
//! 4. it then "deploys" (starts its busy clock), folds the buffered
//!    updates with `XlaFusion::pair_merge`, waits for stragglers, fuses
//!    them on arrival, publishes, and stops its busy clock.
//!
//! `EagerAlwaysOn` mode keeps the aggregator's busy clock running for the
//! entire round — the baseline the container-second savings are measured
//! against. The end-to-end example (`examples/federated_train.rs`) logs
//! the loss curve this produces; EXPERIMENTS.md records it.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::estimator::PeriodicityTracker;
use crate::fusion::Aggregator;
use crate::party::synth_party_dataset;
use crate::runtime::{Runtime, Trainer, XlaFusion, MLP_CLASSES, MLP_IN};
use crate::util::rng::Rng;

/// Accounting mode for the live aggregator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LiveStrategy {
    /// Defer deployment to `t_rnd − t_agg·(1+margin)`.
    Jit { margin: f64 },
    /// Busy from round start to publish (always-on baseline).
    EagerAlwaysOn,
}

#[derive(Clone, Debug)]
pub struct LiveConfig {
    pub n_parties: usize,
    pub rounds: u32,
    /// Minibatches per local epoch — must match a `train_epoch_n{n}_b32`
    /// artifact (2, 4, 8, 16 or 32).
    pub minibatches: usize,
    pub lr: f32,
    pub strategy: LiveStrategy,
    /// Dirichlet alpha for non-IID label skew.
    pub alpha: f64,
    pub seed: u64,
    /// FedProx server pull (0 = plain FedAvg).
    pub mu: f32,
    /// Extra per-epoch delay (ms) — emulates heavier local datasets than
    /// the MLP can express on this box (keeps epoch time >> t_agg so the
    /// JIT deferral window is meaningful, as in the paper's workloads).
    pub extra_epoch_ms: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            n_parties: 8,
            rounds: 30,
            minibatches: 8,
            lr: 0.08,
            strategy: LiveStrategy::Jit { margin: 0.15 },
            alpha: 0.5,
            seed: 42,
            mu: 0.0,
            extra_epoch_ms: 0,
        }
    }
}

/// One round's log line.
#[derive(Clone, Debug)]
pub struct LiveRound {
    pub round: u32,
    /// Mean local training loss across parties.
    pub train_loss: f32,
    /// Global-model loss/accuracy on the held-out batch.
    pub eval_loss: f32,
    pub eval_acc: f32,
    /// §6.2 latency: publish − last update arrival.
    pub agg_latency_secs: f64,
    /// Aggregator busy (container) seconds this round.
    pub agg_busy_secs: f64,
    pub round_secs: f64,
    /// How long aggregation was deferred (JIT) this round.
    pub defer_secs: f64,
}

#[derive(Clone, Debug)]
pub struct LiveReport {
    pub strategy: &'static str,
    pub rounds: Vec<LiveRound>,
    pub total_busy_secs: f64,
    pub total_secs: f64,
    pub t_pair_secs: f64,
    pub final_acc: f32,
}

impl LiveReport {
    pub fn mean_latency_secs(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.agg_latency_secs).sum::<f64>() / self.rounds.len() as f64
    }
}

struct PartyMsg {
    party: usize,
    update: Vec<f32>,
    weight: f32,
    epoch_secs: f64,
    train_loss: f32,
    sent_at: Instant,
}

/// Run a live federated training job. Blocking; spawns one thread per
/// party (each with its own PJRT client).
pub fn run_live(cfg: &LiveConfig) -> Result<LiveReport> {
    let dir = crate::runtime::default_artifact_dir();
    let rt = Runtime::new(&dir).context("aggregator runtime")?;
    let fusion = XlaFusion::new(&rt);

    // Offline t_pair calibration on the actual fusion path (§5.4).
    let spec = crate::model::zoo::mlp_default();
    let t_pair = {
        let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
        let a = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let b = crate::model::ModelUpdate::random(&spec, &mut rng, 1.0);
        let mut acc = a.data.clone();
        fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?; // warm-up/compile
        let t0 = Instant::now();
        for _ in 0..3 {
            fusion.pair_merge(&mut acc, 1.0, &b.data, 1.0)?;
        }
        t0.elapsed().as_secs_f64() / 3.0
    };

    // Global init + held-out eval batch (near-uniform labels).
    let init = Trainer::init(&rt, cfg.seed);
    let global0 = init.flatten();
    let (eval_x, eval_y) = synth_party_dataset(usize::MAX - 1, 256, MLP_IN, MLP_CLASSES, 50.0, cfg.seed);

    let items = cfg.minibatches * 32;
    let (update_tx, update_rx) = mpsc::channel::<PartyMsg>();
    // The global model is broadcast as one shared Arc per round instead of
    // n_parties deep clones of a model-sized Vec.
    let mut model_txs: Vec<mpsc::Sender<Option<Arc<Vec<f32>>>>> = Vec::new();
    let mut handles = Vec::new();
    for party in 0..cfg.n_parties {
        let (mtx, mrx) = mpsc::channel::<Option<Arc<Vec<f32>>>>();
        model_txs.push(mtx);
        let utx = update_tx.clone();
        let cfgc = cfg.clone();
        let dirc = dir.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let rt = Runtime::new(&dirc).context("party runtime")?;
            let (xs, ys) =
                synth_party_dataset(party, items, MLP_IN, MLP_CLASSES, cfgc.alpha, cfgc.seed);
            let mut trainer = Trainer::init(&rt, cfgc.seed);
            while let Ok(Some(global)) = mrx.recv() {
                trainer.unflatten(&global);
                let t0 = Instant::now();
                let loss = trainer.epoch(cfgc.minibatches, &xs, &ys, cfgc.lr)?;
                if cfgc.extra_epoch_ms > 0 {
                    std::thread::sleep(Duration::from_millis(cfgc.extra_epoch_ms));
                }
                let epoch_secs = t0.elapsed().as_secs_f64();
                utx.send(PartyMsg {
                    party,
                    update: trainer.flatten(),
                    weight: items as f32,
                    epoch_secs,
                    train_loss: loss,
                    sent_at: Instant::now(),
                })
                .map_err(|_| anyhow!("aggregator hung up"))?;
            }
            Ok(())
        }));
    }
    drop(update_tx);

    let mut histories = vec![PeriodicityTracker::new(6); cfg.n_parties];
    let mut global = Arc::new(global0);
    let mut rounds = Vec::new();
    let job_start = Instant::now();
    let mut total_busy = 0.0;
    // Round-persistent hot-path state: the aggregator (reset, not
    // reallocated, each round) and one evaluation trainer.
    let mut agg = Aggregator::new(global.len());
    let mut eval_trainer = Trainer::init(&rt, cfg.seed);

    for round in 0..cfg.rounds {
        let round_start = Instant::now();
        for tx in &model_txs {
            tx.send(Some(Arc::clone(&global)))
                .map_err(|_| anyhow!("party hung up"))?;
        }

        // Fig 6: predict t_rnd from per-party histories, t_agg from t_pair.
        let t_upd_max = histories
            .iter()
            .map(|h| h.predict().unwrap_or(0.0))
            .fold(0.0f64, f64::max);
        let t_agg = cfg.n_parties as f64 * t_pair * 1.5 + 0.002;
        let defer = match cfg.strategy {
            LiveStrategy::Jit { margin } => (t_upd_max - t_agg * (1.0 + margin)).max(0.0),
            LiveStrategy::EagerAlwaysOn => 0.0,
        };

        // Collect updates; only *deploy* (busy clock) after the defer point.
        let mut buffered: Vec<PartyMsg> = Vec::new();
        let deadline = round_start + Duration::from_secs_f64(defer);
        loop {
            let now = Instant::now();
            if now >= deadline || buffered.len() == cfg.n_parties {
                break;
            }
            match update_rx.recv_timeout(deadline - now) {
                Ok(m) => buffered.push(m),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(e) => return Err(anyhow!("update channel: {e}")),
            }
        }

        // "Deployment": aggregation busy period starts here.
        let busy_start = match cfg.strategy {
            LiveStrategy::Jit { .. } => Instant::now(),
            LiveStrategy::EagerAlwaysOn => round_start,
        };
        agg.reset();
        let mut last_arrival = round_start;
        let mut train_loss_sum = 0.0f32;
        let mut fused = 0usize;
        let fold = |m: PartyMsg,
                        agg: &mut Aggregator,
                        histories: &mut Vec<PeriodicityTracker>|
         -> Result<()> {
            histories[m.party].observe(m.epoch_secs);
            if agg.n_merged == 0 {
                agg.acc.copy_from_slice(&m.update);
                agg.weight = m.weight;
                agg.n_merged = 1;
            } else {
                let w_acc = agg.weight;
                fusion.pair_merge(&mut agg.acc, w_acc, &m.update, m.weight)?;
                agg.weight += m.weight;
                agg.n_merged += 1;
            }
            Ok(())
        };
        for m in buffered {
            last_arrival = last_arrival.max(m.sent_at);
            train_loss_sum += m.train_loss;
            fused += 1;
            fold(m, &mut agg, &mut histories)?;
        }
        while fused < cfg.n_parties {
            let m = update_rx
                .recv()
                .map_err(|e| anyhow!("update channel: {e}"))?;
            last_arrival = last_arrival.max(m.sent_at);
            train_loss_sum += m.train_loss;
            fused += 1;
            fold(m, &mut agg, &mut histories)?;
        }
        // FedProx-style pull toward the previous global, if configured.
        let fused_model = if cfg.mu > 0.0 {
            let views = [agg.acc.as_slice()];
            fusion.fedprox(&views, &[1.0], &global, cfg.mu)?
        } else {
            agg.acc.clone()
        };
        global = Arc::new(fused_model);
        let publish = Instant::now();
        let busy = (publish - busy_start).as_secs_f64();
        total_busy += busy;

        // Evaluate the global model (trainer reused across rounds).
        eval_trainer.unflatten(&global);
        let (eval_loss, eval_acc) = eval_trainer.eval(&eval_x, &eval_y)?;

        rounds.push(LiveRound {
            round,
            train_loss: train_loss_sum / cfg.n_parties as f32,
            eval_loss,
            eval_acc,
            agg_latency_secs: (publish - last_arrival).as_secs_f64().max(0.0),
            agg_busy_secs: busy,
            round_secs: (publish - round_start).as_secs_f64(),
            defer_secs: defer,
        });
    }

    for tx in &model_txs {
        let _ = tx.send(None);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("party thread panicked"))??;
    }

    let final_acc = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
    Ok(LiveReport {
        strategy: match cfg.strategy {
            LiveStrategy::Jit { .. } => "jit",
            LiveStrategy::EagerAlwaysOn => "eager-ao",
        },
        rounds,
        total_busy_secs: total_busy,
        total_secs: job_start.elapsed().as_secs_f64(),
        t_pair_secs: t_pair,
        final_acc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        crate::runtime::xla_enabled()
            && crate::runtime::default_artifact_dir()
                .join("manifest.json")
                .exists()
    }

    #[test]
    fn live_jit_trains_and_defers() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = LiveConfig {
            n_parties: 3,
            rounds: 4,
            minibatches: 2,
            extra_epoch_ms: 400,
            ..Default::default()
        };
        let report = run_live(&cfg).expect("live run");
        assert_eq!(report.rounds.len(), 4);
        assert!(report.t_pair_secs > 0.0);
        // loss decreases over rounds (real learning through all 3 layers)
        let first = report.rounds.first().unwrap().eval_loss;
        let last = report.rounds.last().unwrap().eval_loss;
        assert!(
            last < first,
            "eval loss should drop: {first} -> {last}"
        );
        // rounds after the first have history -> nonzero deferral
        assert!(
            report.rounds[1..].iter().any(|r| r.defer_secs > 0.0),
            "JIT should defer once epoch times are known"
        );
    }

    #[test]
    fn live_jit_cheaper_than_always_on() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let base = LiveConfig {
            n_parties: 3,
            rounds: 4,
            minibatches: 2,
            extra_epoch_ms: 400,
            ..Default::default()
        };
        let jit = run_live(&base).unwrap();
        let ao = run_live(&LiveConfig {
            strategy: LiveStrategy::EagerAlwaysOn,
            ..base
        })
        .unwrap();
        assert!(
            jit.total_busy_secs < ao.total_busy_secs,
            "jit busy {} !< ao busy {}",
            jit.total_busy_secs,
            ao.total_busy_secs
        );
    }
}
