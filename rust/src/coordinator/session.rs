//! `Session` — the one way to run anything on this platform.
//!
//! The paper's claim is that JIT aggregation is a *drop-in* scheduling
//! discipline for an FL platform (§3, §5); the repo had grown five
//! divergent entry points (`Platform::run`, `run_scenario`,
//! `broker::run_trace`, `run_live`/`run_live_on`, `run_live_broker`)
//! with three incompatible report types. This module collapses them into
//! one builder-style façade:
//!
//! ```no_run
//! use fljit::coordinator::session::Session;
//! use fljit::coordinator::job::FlJobSpec;
//! use fljit::party::FleetKind;
//! use fljit::workloads::Workload;
//!
//! let spec = FlJobSpec::new(Workload::mlp_live(), FleetKind::ActiveHomogeneous, 4, 3);
//! let mut s = Session::live().seed(7).dim(64);
//! let job = s.job(spec, "jit");
//! let events = s.events();
//! let report = s.run().unwrap();
//! println!("{} rounds", report.job(job).records.len());
//! for ev in events.try_iter() {
//!     println!("{ev:?}");
//! }
//! ```
//!
//! ## The three time regimes (builder constructors)
//!
//! | constructor | clock | parties | data plane | paper section |
//! |---|---|---|---|---|
//! | [`Session::sim`] | virtual (event-driven) | fleet model arrivals | emulated merges | §6 grids, Fig 7/8/9 |
//! | [`Session::live`] | instant mock of the wall clock | scripted publishes into the MQ | real folds + §5.5 checkpoints | sim/live equivalence |
//! | [`Session::wall`] | real wall clock | OS threads (synthetic or XLA training) or scripted | real folds + §5.5 checkpoints | §5 end-to-end |
//!
//! All three drive the *same* [`JobEngine`](crate::coordinator::driver::JobEngine)
//! + `Strategy` code; `live` and `wall` share one multi-job control loop
//! (`coordinator::live`), of which a single job is simply the N = 1 case.
//!
//! ## Builder knobs → paper sections
//!
//! | knob | meaning | paper |
//! |---|---|---|
//! | [`job`](Session::job) / [`job_at`](Session::job_at) | admit an [`FlJobSpec`] under a strategy (returns a [`JobHandle`]) | §5.1 job spec, §3 designs |
//! | [`trace`](Session::trace) | replay a whole [`JobTrace`] (arrivals over time) | §6.3 job-mix economics |
//! | [`policy`](Session::policy) | cross-job arbitration (`deadline` \| `least-slack` \| `wfs`) | §5.5 priorities |
//! | [`admission`](Session::admission) | container-demand quotas + SLO queueing | §6.3 shared cluster |
//! | [`resume`](Session::resume) | reconstruct every job from the MQ after an aggregator death | §5.5 checkpointing |
//! | [`quorum` (on the spec)](crate::coordinator::job::FlJobSpec::with_quorum) | minimum updates per round | §5.1 |
//! | [`backend`](Session::backend) | who plays the parties in a `wall` session | §4 party model |
//! | [`kill_after_fuses`](Session::kill_after_fuses) | aggregator-crash injection for the resume tests | §5.5 |
//! | [`shards`](Session::shards) | L1 aggregator tree width (bit-identical to the single fold for every n) | §3.2 hierarchy |
//! | [`kill_shard`](Session::kill_shard) | kill one L1 shard mid-round; it resumes from its own checkpoint | §5.5 |
//! | [`faults`](Session::faults) | fleet fault injection ([`FleetFaults`]): stragglers, dropout, diurnal waves, weight skew | robustness matrix |
//! | [`adaptive`](Session::adaptive) | online arrival estimation ([`AdaptiveConfig`](crate::adapt::AdaptiveConfig)): learned fuse deadlines, quorum restore, admission autoscale | adaptive JIT (PR 10) |
//! | [`events`](Session::events) | stream typed [`SessionEvent`]s while the run executes | §5.5 observability |
//! | [`telemetry`](Session::telemetry) | attach a [`Registry`](crate::telemetry::Registry): metrics + structured spans from every layer | §5.5 observability |
//!
//! Every variant returns the same unified [`Report`] (one enum over a
//! shared [`RunSummary`] body), which subsumes the legacy
//! `JobReport`/`RunStats`/`BrokerReport`/`LiveReport`/`LiveBrokerReport`
//! quintet.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::adapt::AdaptiveConfig;
use crate::broker::admission::{AdmissionConfig, AdmissionController};
use crate::broker::workload::{JobArrival, JobTrace};
use crate::broker::{arbitration, SloClass};
use crate::coordinator::driver::{InstantClock, JobEngine, WallClock, WallDriver};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::live::{
    self, LiveRoundStats, PartyBackend, ScriptedParties, ThreadParties,
};
use crate::coordinator::platform::{scenario_capacity, Platform, PlatformConfig};
use crate::metrics::{RoundRecord, AZURE_USD_PER_CONTAINER_SECOND};
use crate::mq::MessageQueue;
use crate::party::FleetFaults;
use crate::sim::secs;
use crate::telemetry::Registry;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::wal::{FsyncPolicy, WalConfig};

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

/// A typed observation from a running session, streamed through the
/// channel handed out by [`Session::events`]. The sequence is a
/// deterministic function of (mode, jobs, seed) for `sim` and `live`
/// sessions (pinned by test); `wall` sessions order events by real time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SessionEvent {
    /// A job's submission reached the broker (its `JobArrival` fired).
    JobSubmitted { job: usize, at_secs: f64 },
    /// Admission control had no headroom: the job waits in the SLO queue.
    JobQueued { job: usize, at_secs: f64 },
    /// The job cleared admission (immediately, or released by a finishing
    /// job's freed demand) and its next round was scheduled.
    JobAdmitted { job: usize, at_secs: f64 },
    /// A round began: the global model went out to the round's parties.
    RoundStarted { job: usize, round: u32, at_secs: f64 },
    /// A round was skipped on starvation: expected on-time arrivals fell
    /// below the quorum floor (fault injection's graceful-degradation
    /// rule), so the engine moved on instead of hanging.
    RoundSkipped { job: usize, round: u32, at_secs: f64 },
    /// The data plane folded `folds` updates and checkpointed the partial
    /// aggregate to the MQ after each one (§5.5). Live/wall only.
    CheckpointWritten {
        job: usize,
        round: u32,
        folds: u64,
        at_secs: f64,
    },
    /// A round completed: the fused model is available (and, on the live
    /// paths, published to the job's model topic).
    RoundFused {
        job: usize,
        round: u32,
        latency_secs: f64,
        at_secs: f64,
    },
    /// The cluster preempted a running aggregation task (victim chosen by
    /// the arbitration policy, §5.5).
    Preempted { task: usize, at_secs: f64 },
    /// A job finished its last round.
    JobFinished { job: usize, at_secs: f64 },
    /// Fault injection tripped (`kill_after_fuses`): the aggregator died
    /// mid-round, leaving the MQ intact for a `resume` session.
    Crashed { at_secs: f64 },
}

/// Cheap cloneable handle the runners emit events through. Inactive by
/// default (every emit is a no-op until [`Session::events`] installs a
/// channel), so the hot paths pay one `Option` check.
#[derive(Clone, Default)]
pub struct EventSink {
    tx: Option<Sender<SessionEvent>>,
    /// Set the first time a send fails (receiver dropped). Shared across
    /// clones so every emitter in the run degrades to a no-op together —
    /// a consumer hanging up mid-run must never wedge or panic the loop,
    /// and `active()` going false lets hot paths skip event assembly.
    closed: Arc<AtomicBool>,
}

impl EventSink {
    /// A sink that drops everything.
    pub fn none() -> EventSink {
        EventSink::default()
    }

    fn with_sender(tx: Sender<SessionEvent>) -> EventSink {
        EventSink {
            tx: Some(tx),
            closed: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Is anyone listening? Lets callers skip event assembly entirely.
    /// Goes false permanently once the receiver hangs up.
    pub fn active(&self) -> bool {
        self.tx.is_some() && !self.closed.load(Ordering::Relaxed)
    }

    /// Emit an event. No-op without a listener; the first send error (a
    /// dropped receiver) latches the shared `closed` flag so every clone
    /// of this sink stops emitting — hanging up is always safe.
    pub fn emit(&self, ev: SessionEvent) {
        let Some(tx) = &self.tx else { return };
        if self.closed.load(Ordering::Relaxed) {
            return;
        }
        if tx.send(ev).is_err() {
            self.closed.store(true, Ordering::Relaxed);
        }
    }

    /// Stream every preemption decision the cluster logged since `*seen`
    /// as a [`SessionEvent::Preempted`], advancing the cursor. Both
    /// runners (sim platform and live loop) call this after each event
    /// dispatch — and the live loop once more after its loop exits, so
    /// decisions made by a crashing dispatch still reach the stream; the
    /// event sequence and the report's `preemptions` list must agree.
    pub(crate) fn stream_preemptions(
        &self,
        cluster: &crate::cluster::Cluster,
        seen: &mut usize,
    ) {
        if !self.active() {
            return;
        }
        let log = cluster.preemption_log();
        while *seen < log.len() {
            let (t, task) = log[*seen];
            self.emit(SessionEvent::Preempted {
                task,
                at_secs: crate::sim::to_secs(t),
            });
            *seen += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// the unified report
// ---------------------------------------------------------------------------

/// Opaque per-job handle returned by [`Session::job`]; index it into the
/// run's [`Report`] with [`Report::job`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobHandle(pub(crate) usize);

impl JobHandle {
    /// The dense platform job id (also the job's index in
    /// [`RunSummary::jobs`] and its MQ topic namespace).
    pub fn id(self) -> usize {
        self.0
    }
}

/// One job's outcome, identical in shape across every session mode —
/// the union of the legacy `JobReport`, `BrokerJobOutcome`,
/// `LiveReport` and `LiveJobOutcome` fields. Sim-only fields are zero /
/// empty on the live paths and vice versa (`final_model` is empty in
/// sim; `updates_folded` is 0 in sim).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub job: usize,
    pub name: String,
    pub strategy: String,
    pub workload: String,
    pub fleet: String,
    pub class: SloClass,
    pub parties: usize,
    /// Submission time (virtual seconds from session start).
    pub arrival_secs: f64,
    /// Admission backpressure: seconds queued before the job started.
    pub queue_wait_secs: f64,
    /// Strategy round records (§6.2 latency semantics, same everywhere).
    pub records: Vec<RoundRecord>,
    /// Aggregation container-seconds from the cluster ledger.
    pub container_seconds: f64,
    /// Ancillary-service container-seconds (MongoDB/Kafka/COS share).
    pub ancillary_seconds: f64,
    pub deployments: u64,
    /// Emulated update merges (the simulator-comparable count).
    pub updates_fused: u64,
    /// Real data-plane folds this run performed for the job (0 in sim).
    pub updates_folded: u64,
    /// Absolute virtual-time instant the job finished (0.0 if it did not).
    pub makespan_secs: f64,
    /// Latest published global model (live/wall; empty in sim).
    pub final_model: Vec<f32>,
    /// Set on resumed runs: the round reconstructed from the job's MQ
    /// state (model-topic offset).
    pub resumed_round: Option<u32>,
    /// XLA backend: per-round train/eval stats.
    pub stats: Vec<LiveRoundStats>,
    /// XLA backend: measured pair-fusion time (§5.4 calibration).
    pub t_pair_secs: f64,
    /// Sim with [`Session::solo_baselines`]: the same job's mean latency
    /// alone on an uncontended cluster.
    pub solo_mean_latency_secs: Option<f64>,
    /// Updates cut at the straggler deadline (drop-policy strategies) or
    /// whose payload vanished before a decayed fold. 0 without faults.
    pub updates_dropped: usize,
    /// Deadline-missers folded with decayed weight (`async-stale` only).
    pub updates_decayed: usize,
    /// Rounds skipped on starvation (expected on-time arrivals below the
    /// quorum floor). 0 without faults.
    pub rounds_skipped: u32,
}

impl JobOutcome {
    /// Mean aggregation latency over rounds — the Fig 7/8 metric.
    pub fn mean_latency_secs(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.latency_secs).sum::<f64>() / self.records.len() as f64
    }

    pub fn latency_p95(&self) -> f64 {
        if self.records.is_empty() {
            // percentile() of nothing is NaN, which would poison the
            // schema-stable JSON export (NaN is not valid JSON)
            return 0.0;
        }
        percentile(
            &self.records.iter().map(|r| r.latency_secs).collect::<Vec<_>>(),
            95.0,
        )
    }

    /// Total container-seconds (aggregation + ancillary) — the Fig 9 metric.
    pub fn total_container_seconds(&self) -> f64 {
        self.container_seconds + self.ancillary_seconds
    }

    /// Projected cost in USD (Fig 9).
    pub fn cost_usd(&self) -> f64 {
        self.total_container_seconds() * AZURE_USD_PER_CONTAINER_SECOND
    }

    /// Contended / solo mean-latency ratio (1.0 = no inflation).
    pub fn latency_inflation(&self) -> Option<f64> {
        let solo = self.solo_mean_latency_secs?;
        if solo <= 0.0 {
            return None;
        }
        Some(self.mean_latency_secs() / solo)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::num(self.job as f64)),
            ("name", Json::str(&self.name)),
            ("strategy", Json::str(&self.strategy)),
            ("workload", Json::str(&self.workload)),
            ("fleet", Json::str(&self.fleet)),
            ("class", Json::str(self.class.name())),
            ("parties", Json::num(self.parties as f64)),
            ("arrival_secs", Json::num(self.arrival_secs)),
            ("queue_wait_secs", Json::num(self.queue_wait_secs)),
            ("rounds", Json::num(self.records.len() as f64)),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::num(r.round as f64)),
                                ("latency_secs", Json::num(r.latency_secs)),
                                ("last_arrival_secs", Json::num(r.last_arrival_secs)),
                                ("complete_secs", Json::num(r.complete_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("mean_latency_secs", Json::num(self.mean_latency_secs())),
            ("latency_p95_secs", Json::num(self.latency_p95())),
            ("container_seconds", Json::num(self.container_seconds)),
            ("ancillary_seconds", Json::num(self.ancillary_seconds)),
            (
                "total_container_seconds",
                Json::num(self.total_container_seconds()),
            ),
            ("cost_usd", Json::num(self.cost_usd())),
            ("deployments", Json::num(self.deployments as f64)),
            ("updates_fused", Json::num(self.updates_fused as f64)),
            ("updates_folded", Json::num(self.updates_folded as f64)),
            ("updates_dropped", Json::num(self.updates_dropped as f64)),
            ("updates_decayed", Json::num(self.updates_decayed as f64)),
            ("rounds_skipped", Json::num(self.rounds_skipped as f64)),
            ("makespan_secs", Json::num(self.makespan_secs)),
            ("final_model_dim", Json::num(self.final_model.len() as f64)),
            (
                "resumed_round",
                match self.resumed_round {
                    Some(r) => Json::num(r as f64),
                    None => Json::Null,
                },
            ),
            (
                "solo_mean_latency_secs",
                match self.solo_mean_latency_secs {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                },
            ),
            ("t_pair_secs", Json::num(self.t_pair_secs)),
            (
                "eval_stats",
                Json::Arr(
                    self.stats
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("round", Json::num(s.round as f64)),
                                ("train_loss", Json::num(s.train_loss as f64)),
                                ("eval_loss", Json::num(s.eval_loss as f64)),
                                ("eval_acc", Json::num(s.eval_acc as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The shared body of every [`Report`] variant: per-job outcomes plus
/// run-level cluster aggregates.
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Arbitration policy the shared cluster ran under.
    pub policy: String,
    /// Cluster container capacity.
    pub capacity: usize,
    pub seed: u64,
    pub jobs: Vec<JobOutcome>,
    /// Σ container-seconds / (capacity × span).
    pub cluster_utilization: f64,
    pub total_container_seconds: f64,
    /// Virtual-time span of the run (seconds).
    pub span_secs: f64,
    /// Real data-plane folds across all jobs (0 in sim).
    pub updates_folded: u64,
    /// Preemption decisions `(secs, victim task)` in decision order —
    /// the policy-determinism pin.
    pub preemptions: Vec<(f64, usize)>,
    /// Real elapsed time of the run itself.
    pub wall_secs: f64,
    /// True when `kill_after_fuses` fired: the run aborted mid-round and
    /// the MQ holds every job's durable state for a `resume` session.
    pub crashed: bool,
}

impl RunSummary {
    pub fn mean_queue_wait_secs(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        self.jobs.iter().map(|j| j.queue_wait_secs).sum::<f64>() / self.jobs.len() as f64
    }

    pub fn mean_latency_inflation(&self) -> Option<f64> {
        let vals: Vec<f64> = self
            .jobs
            .iter()
            .filter_map(|j| j.latency_inflation())
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Peak number of jobs simultaneously running.
    pub fn max_concurrent_jobs(&self) -> usize {
        crate::broker::peak_concurrency(self.jobs.iter().map(|o| {
            (o.arrival_secs + o.queue_wait_secs, o.makespan_secs)
        }))
    }
}

/// The unified run report: one variant per time regime, all sharing the
/// [`RunSummary`] body — this enum subsumes the legacy
/// `JobReport`/`RunStats`/`BrokerReport`/`LiveReport`/`LiveBrokerReport`.
#[derive(Clone, Debug)]
pub enum Report {
    /// Virtual-time simulation ([`Session::sim`]).
    Sim(RunSummary),
    /// Live data plane on the instant clock ([`Session::live`]).
    Live(RunSummary),
    /// Live data plane on the real wall clock ([`Session::wall`]).
    Wall(RunSummary),
}

impl Report {
    pub fn summary(&self) -> &RunSummary {
        match self {
            Report::Sim(s) | Report::Live(s) | Report::Wall(s) => s,
        }
    }

    pub fn mode_name(&self) -> &'static str {
        match self {
            Report::Sim(_) => "sim",
            Report::Live(_) => "live",
            Report::Wall(_) => "wall",
        }
    }

    pub fn jobs(&self) -> &[JobOutcome] {
        &self.summary().jobs
    }

    /// The outcome of the job admitted under `h`.
    pub fn job(&self, h: JobHandle) -> &JobOutcome {
        &self.summary().jobs[h.0]
    }

    /// Single-job convenience: the first (only) job's outcome.
    pub fn single(&self) -> &JobOutcome {
        &self.summary().jobs[0]
    }

    /// Schema-stable JSON export (pinned by the golden-file test): the
    /// same key set for every mode, with mode-inapplicable fields zeroed
    /// or null rather than omitted.
    pub fn to_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("mode", Json::str(self.mode_name())),
            ("policy", Json::str(&s.policy)),
            ("capacity", Json::num(s.capacity as f64)),
            ("seed", Json::num(s.seed as f64)),
            ("crashed", Json::Bool(s.crashed)),
            ("span_secs", Json::num(s.span_secs)),
            ("wall_secs", Json::num(s.wall_secs)),
            ("cluster_utilization", Json::num(s.cluster_utilization)),
            (
                "total_container_seconds",
                Json::num(s.total_container_seconds),
            ),
            ("updates_folded", Json::num(s.updates_folded as f64)),
            ("mean_queue_wait_secs", Json::num(s.mean_queue_wait_secs())),
            (
                "max_concurrent_jobs",
                Json::num(s.max_concurrent_jobs() as f64),
            ),
            (
                "preemptions",
                Json::Arr(
                    s.preemptions
                        .iter()
                        .map(|&(t, task)| {
                            Json::obj(vec![
                                ("at_secs", Json::num(t)),
                                ("task", Json::num(task as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "jobs",
                Json::Arr(s.jobs.iter().map(|j| j.to_json()).collect()),
            ),
        ])
    }
}

/// Flatten a JSON value into sorted `path: type` lines — the schema the
/// golden-file test pins (values change run to run, the shape must not).
pub fn json_schema_lines(v: &Json) -> Vec<String> {
    fn walk(prefix: &str, v: &Json, out: &mut Vec<String>) {
        if let Some(obj) = v.as_obj() {
            for (k, child) in obj {
                walk(&format!("{prefix}.{k}"), child, out);
            }
        } else if let Some(arr) = v.as_arr() {
            match arr.first() {
                Some(first) => walk(&format!("{prefix}[]"), first, out),
                None => out.push(format!("{prefix}[]: (empty)")),
            }
        } else {
            let ty = if v.as_str().is_some() {
                "str"
            } else if v.as_bool().is_some() {
                "bool"
            } else if v.as_f64().is_some() {
                "num"
            } else {
                "null"
            };
            out.push(format!("{prefix}: {ty}"));
        }
    }
    let mut out = Vec::new();
    walk("", v, &mut out);
    out.sort();
    out
}

// ---------------------------------------------------------------------------
// the builder
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Sim,
    Live,
    Wall,
}

/// Builder-style façade over every execution regime. See the module docs
/// for the knob table; construct with [`Session::sim`], [`Session::live`]
/// or [`Session::wall`], add jobs, then [`run`](Session::run).
pub struct Session {
    mode: Mode,
    arrivals: Vec<JobArrival>,
    policy: String,
    admission: Option<AdmissionConfig>,
    capacity: Option<usize>,
    seed: u64,
    dim: usize,
    lr: f32,
    backend: Option<PartyBackend>,
    minibatches: usize,
    alpha: f64,
    kill_after_fuses: Option<u64>,
    shards: usize,
    kill_shard: Option<live::ShardKill>,
    mq: Option<Arc<MessageQueue>>,
    data_dir: Option<std::path::PathBuf>,
    fsync: FsyncPolicy,
    resume: bool,
    solo_baselines: bool,
    sink: EventSink,
    faults: FleetFaults,
    adaptive: AdaptiveConfig,
    telemetry: Registry,
}

impl Session {
    fn with_mode(mode: Mode) -> Session {
        Session {
            mode,
            arrivals: Vec::new(),
            policy: "deadline".to_string(),
            admission: None,
            capacity: None,
            seed: 42,
            dim: 512,
            lr: 0.3,
            backend: None,
            minibatches: 4,
            alpha: 0.5,
            kill_after_fuses: None,
            shards: 1,
            kill_shard: None,
            mq: None,
            data_dir: None,
            fsync: FsyncPolicy::default(),
            resume: false,
            solo_baselines: false,
            sink: EventSink::none(),
            faults: FleetFaults::none(),
            adaptive: AdaptiveConfig::none(),
            telemetry: Registry::disabled(),
        }
    }

    /// Virtual-time simulation: fleet-model arrivals, emulated merges —
    /// the Fig 7/8/9 grid regime (10k parties × 50 rounds in
    /// milliseconds of wall time).
    pub fn sim() -> Session {
        Session::with_mode(Mode::Sim)
    }

    /// The live data plane on an instant clock: scripted parties publish
    /// real update vectors into the zero-copy MQ at the fleet model's
    /// drawn offsets and the aggregator folds them with per-fold §5.5
    /// checkpoints — deterministic, bit-identical to `sim` (pinned by
    /// `tests/live_equivalence.rs`), and the regime every resume test
    /// runs in.
    pub fn live() -> Session {
        Session::with_mode(Mode::Live)
    }

    /// The live data plane on the real wall clock: the driver sleeps to
    /// the next deadline and wakes on MQ publishes from party threads
    /// (synthetic local training by default, real XLA training with
    /// [`backend(PartyBackend::XlaThreads)`](Session::backend)).
    pub fn wall() -> Session {
        Session::with_mode(Mode::Wall)
    }

    /// Admit a job at t = 0 under `strategy` (any of the six §3
    /// designs, `async-stale` included). Returns a [`JobHandle`] to
    /// index the [`Report`] with.
    pub fn job(&mut self, spec: FlJobSpec, strategy: &str) -> JobHandle {
        self.job_at(spec, strategy, 0.0, SloClass::Standard)
    }

    /// Admit a job arriving at `at_secs` (virtual seconds) in `class` —
    /// the broker path: the job passes admission control and shares the
    /// arbitrated cluster.
    pub fn job_at(
        &mut self,
        spec: FlJobSpec,
        strategy: &str,
        at_secs: f64,
        class: SloClass,
    ) -> JobHandle {
        self.arrivals.push(JobArrival {
            at_secs,
            spec,
            strategy: strategy.to_string(),
            class,
        });
        JobHandle(self.arrivals.len() - 1)
    }

    /// Replace the session's job list with a whole [`JobTrace`] (§6.3):
    /// jobs arrive at their trace times in trace order. Job `i` of the
    /// trace is job `i` of the report.
    pub fn trace(mut self, trace: &JobTrace) -> Session {
        self.arrivals = trace.arrivals.clone();
        self
    }

    /// Cross-job arbitration policy (`deadline` — the §5.5 baseline,
    /// default — `least-slack`, or `wfs`). Drives both task starts and
    /// preemption-victim choice.
    pub fn policy(mut self, name: &str) -> Session {
        self.policy = name.to_string();
        self
    }

    /// Admission control (container-demand budget + SLO queueing). The
    /// default config admits effectively everything.
    pub fn admission(mut self, cfg: AdmissionConfig) -> Session {
        self.admission = Some(cfg);
        self
    }

    /// Shared cluster container capacity. Default: a single job gets the
    /// amply-sized `scenario_capacity` of its spec; a multi-job session
    /// gets 16 (scarce on purpose — arbitration needs contention).
    pub fn capacity(mut self, capacity: usize) -> Session {
        self.capacity = Some(capacity);
        self
    }

    /// Platform seed: fleets, arrival draws and synthetic updates are a
    /// deterministic function of (seed, job id).
    pub fn seed(mut self, seed: u64) -> Session {
        self.seed = seed;
        self
    }

    /// Update vector length of the live data plane (ignored in sim and
    /// by the XLA backend, whose model sets the dimension).
    pub fn dim(mut self, dim: usize) -> Session {
        self.dim = dim;
        self
    }

    /// Synthetic local-training pull toward the party target.
    ///
    /// Knob scoping: data-plane knobs (`dim`, `lr`, `minibatches`,
    /// `alpha`) are quietly inert where no data plane exists (sim), and
    /// `solo_baselines` is quietly inert outside sim — they tune a
    /// regime rather than select one. Knobs that *select* behavior the
    /// mode cannot provide (`resume`/`kill_after_fuses` in sim, thread
    /// `backend`s without a wall clock) are hard errors in
    /// [`run`](Session::run).
    pub fn lr(mut self, lr: f32) -> Session {
        self.lr = lr;
        self
    }

    /// Who plays the parties in a [`wall`](Session::wall) session
    /// (default: synthetic training threads for one job, scripted
    /// parties for a multi-job trace). `live` sessions are always
    /// scripted — thread backends need the real clock.
    pub fn backend(mut self, backend: PartyBackend) -> Session {
        self.backend = Some(backend);
        self
    }

    /// XLA backend: minibatches per epoch (2/4/8/16/32 artifacts).
    pub fn minibatches(mut self, minibatches: usize) -> Session {
        self.minibatches = minibatches;
        self
    }

    /// XLA backend: Dirichlet alpha for non-IID label skew.
    pub fn alpha(mut self, alpha: f64) -> Session {
        self.alpha = alpha;
        self
    }

    /// Fault injection: abort the aggregator after this many data-plane
    /// folds across all jobs, leaving the MQ intact for a resume (§5.5
    /// test hook; live/wall only).
    pub fn kill_after_fuses(mut self, folds: Option<u64>) -> Session {
        self.kill_after_fuses = folds;
        self
    }

    /// Aggregator tree: partition each round's parties across `n` L1
    /// aggregator shards (fixed range boundaries over party id), one MQ
    /// topic and §5.5 checkpoint slot per shard, the root folding the
    /// shard partials in shard order. The published models are
    /// bit-identical for every `n` (1..=64; the fold runs over fixed
    /// logical buckets, so the grouping is independent of the shard
    /// count — pinned by `tests/shard_equivalence.rs`). Data-plane knob:
    /// live/wall route real messages per shard; sim has no data plane,
    /// so the knob is quietly inert there.
    pub fn shards(mut self, n: usize) -> Session {
        self.shards = n;
        self
    }

    /// Fault injection: kill L1 aggregator shard `shard` after its
    /// `after_folds`-th fold of the run. Siblings keep folding; a
    /// replacement shard resumes JIT from the dead shard's own WAL
    /// checkpoint slot at round completion. With `mid_checkpoint` the
    /// fatal fold's checkpoint write is itself lost (torn), so the
    /// replacement replays that update from the shard's topic log.
    /// Live/wall only.
    pub fn kill_shard(mut self, shard: usize, after_folds: u64, mid_checkpoint: bool) -> Session {
        self.kill_shard = Some(live::ShardKill {
            shard,
            after_folds,
            torn: mid_checkpoint,
        });
        self
    }

    /// Fleet fault injection ([`FleetFaults`]): heavy-tailed stragglers,
    /// per-round dropout with rejoin, diurnal availability waves, non-IID
    /// weight skew, straggler cutoff and the quorum floor. Applied to
    /// every job, identically in `sim`, `live` and `wall` sessions — the
    /// engine draws the faults from the same seeded rng stream in all
    /// three, so a sim cell and its live twin degrade bit-identically.
    pub fn faults(mut self, faults: FleetFaults) -> Session {
        self.faults = faults;
        self
    }

    /// Adaptive JIT ([`crate::adapt`]): per-job online estimation of the
    /// update-arrival distribution (mergeable quantile sketches fed from
    /// the engine's existing arrival bookkeeping) converted into three
    /// control signals — learned fuse-deadline re-arming, straggler
    /// quorum restore on fault-degraded rounds, and bounded admission
    /// budget autoscaling. Applied to every job, identically in `sim`,
    /// `live` and `wall`; the sketch consumes no rng, so enabled runs
    /// stay bit-identical per seed across regimes, and the default
    /// ([`AdaptiveConfig::none`]) is a zero-cost no-op (same contract as
    /// `faults`). Sketch state checkpoints through the job's MQ slot, so
    /// killed runs resume with their learned distribution intact.
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Session {
        self.adaptive = cfg;
        self
    }

    /// Run against an explicit shared MQ — required for in-process
    /// resume (a fresh private MQ is created otherwise, so nothing
    /// survives the run). For cross-process durability use
    /// [`data_dir`](Session::data_dir) instead.
    pub fn on(mut self, mq: &Arc<MessageQueue>) -> Session {
        self.mq = Some(Arc::clone(mq));
        self
    }

    /// Put the data plane on disk: the session runs on a durable MQ
    /// (segmented mmap WAL) rooted at `dir`. Combined with
    /// [`resume`](Session::resume), a session killed with `kill -9`
    /// picks up from the on-disk log + §5.5 checkpoints. Live/wall only.
    pub fn data_dir<P: Into<std::path::PathBuf>>(mut self, dir: P) -> Session {
        self.data_dir = Some(dir.into());
        self
    }

    /// Fsync policy for [`data_dir`](Session::data_dir) (default
    /// `every=128`; inert without a data dir).
    pub fn fsync(mut self, policy: FsyncPolicy) -> Session {
        self.fsync = policy;
        self
    }

    /// Reconstruct every job's position from the MQ instead of starting
    /// fresh (§5.5): completed rounds from each job's model-topic offset,
    /// in-progress partial aggregates from its checkpoint slot, round
    /// topics replayed into the strategies as arrival events. Jobs that
    /// were still queued at the crash are re-admitted from the session's
    /// job list (which is why resume takes the same jobs/trace, not just
    /// the MQ).
    pub fn resume(mut self, resume: bool) -> Session {
        self.resume = resume;
        self
    }

    /// Sim only (inert elsewhere): also run each job solo on an
    /// uncontended cluster and report `solo_mean_latency_secs` / latency
    /// inflation (doubles the work).
    pub fn solo_baselines(mut self, with_solo: bool) -> Session {
        self.solo_baselines = with_solo;
        self
    }

    /// Install and return the event stream: the run emits typed
    /// [`SessionEvent`]s through it as they happen. Consume live from
    /// another thread (wall sessions), or drain after [`run`](Session::run)
    /// returns — the channel is unbounded and buffers everything.
    /// Dropping the receiver at any point is safe: emitters degrade to
    /// silent no-ops from the first failed send onward.
    pub fn events(&mut self) -> Receiver<SessionEvent> {
        let (tx, rx) = channel();
        self.sink = EventSink::with_sender(tx);
        rx
    }

    /// Attach a telemetry [`Registry`]: counters, gauges, histograms and
    /// structured spans from every layer the run touches (engine rounds,
    /// MQ depth/wait, admission queueing, cluster deploys/preemptions,
    /// fusion pool). Strictly passive — a disabled registry (the
    /// default) costs one branch per site, and an enabled one observes
    /// the same timestamps the run already computes, so seeded streams
    /// and reports are bit-identical either way (pinned by test).
    pub fn telemetry(mut self, reg: &Registry) -> Session {
        self.telemetry = reg.clone();
        self
    }

    // -- execution ---------------------------------------------------------

    /// The admission config the run will use: the explicit one (or the
    /// default), with the adaptive autoscale bounds applied when the
    /// adaptive policy asks for them and the caller did not pin their
    /// own. Shared by both regimes so sim and live autoscale identically.
    fn admission_cfg(&self) -> AdmissionConfig {
        let mut cfg = self.admission.clone().unwrap_or_default();
        if cfg.autoscale.is_none() {
            cfg.autoscale = self.adaptive.admission_bounds();
        }
        cfg
    }

    fn default_capacity(&self) -> usize {
        if self.arrivals.len() == 1 {
            scenario_capacity(&self.arrivals[0].spec)
        } else {
            16
        }
    }

    /// Run every job to completion (or to the injected kill) and return
    /// the unified [`Report`].
    pub fn run(self) -> Result<Report> {
        if self.arrivals.is_empty() {
            return Err(anyhow!(
                "session has no jobs: add .job(..)/.job_at(..) or .trace(..)"
            ));
        }
        if arbitration::by_name(&self.policy).is_none() {
            return Err(anyhow!(
                "unknown arbitration policy {:?}; expected one of {:?}",
                self.policy,
                arbitration::all_policies()
            ));
        }
        for (job, arr) in self.arrivals.iter().enumerate() {
            if crate::coordinator::strategies::by_name(&arr.strategy).is_none() {
                return Err(anyhow!(
                    "job {job}: unknown strategy {:?}; expected one of {:?}",
                    arr.strategy,
                    crate::coordinator::strategies::all_strategies()
                ));
            }
        }
        match self.mode {
            Mode::Sim => {
                if self.data_dir.is_some() {
                    return Err(anyhow!(
                        "the Sim regime has no data plane to persist: \
                         .data_dir(..) only applies to live()/wall() sessions"
                    ));
                }
                self.run_sim()
            }
            Mode::Live | Mode::Wall => self.run_live_mode(),
        }
    }

    /// Virtual-time regime: the multi-tenant `Platform` under the
    /// virtual driver, with broker admission + arbitration installed.
    fn run_sim(self) -> Result<Report> {
        if self.resume {
            return Err(anyhow!(
                "resume needs a live or wall session (sim has no durable MQ state)"
            ));
        }
        if self.backend.is_some() {
            return Err(anyhow!(
                "party backends apply to wall sessions only (sim emulates arrivals)"
            ));
        }
        if self.kill_after_fuses.is_some() {
            return Err(anyhow!(
                "kill_after_fuses applies to live/wall sessions (sim has no data plane)"
            ));
        }
        if self.kill_shard.is_some() {
            return Err(anyhow!(
                "kill_shard applies to live/wall sessions (sim has no data plane)"
            ));
        }
        let capacity = self.capacity.unwrap_or_else(|| self.default_capacity()).max(1);
        let wall_start = Instant::now();
        let mut pcfg = PlatformConfig {
            seed: self.seed,
            faults: self.faults,
            adaptive: self.adaptive.clone(),
            ..Default::default()
        };
        pcfg.cluster.capacity = capacity;
        let mut platform = Platform::new(pcfg);
        let mut ctrl = AdmissionController::new(self.admission_cfg());
        for arr in &self.arrivals {
            let demand = arr.spec.workload.n_agg(arr.spec.n_parties) as usize;
            let job = platform.submit_at(arr.spec.clone(), &arr.strategy, secs(arr.at_secs));
            ctrl.register(job, demand, arr.class);
            platform.cluster_mut().set_job_weight(job, arr.class.weight());
        }
        platform
            .cluster_mut()
            .set_policy(arbitration::by_name(&self.policy).expect("validated in run"));
        platform.set_admission(ctrl);
        platform.set_event_sink(self.sink.clone());
        platform.set_telemetry(&self.telemetry);
        let (reports, stats) = platform.run_with_stats();
        let ctrl = stats.admission.expect("admission controller returned");
        let span = stats.end_secs;
        let jobs: Vec<JobOutcome> = reports
            .into_iter()
            .enumerate()
            .map(|(job, report)| {
                let arr = &self.arrivals[job];
                JobOutcome {
                    job,
                    name: arr.spec.name.clone(),
                    strategy: arr.strategy.clone(),
                    workload: report.workload,
                    fleet: report.fleet,
                    class: arr.class,
                    parties: arr.spec.n_parties,
                    arrival_secs: arr.at_secs,
                    queue_wait_secs: ctrl.queue_wait_secs(job),
                    records: report.rounds,
                    container_seconds: report.container_seconds,
                    ancillary_seconds: report.ancillary_seconds,
                    deployments: report.deployments,
                    updates_fused: report.updates_fused,
                    updates_folded: 0,
                    makespan_secs: report.makespan_secs,
                    final_model: Vec::new(),
                    resumed_round: None,
                    stats: Vec::new(),
                    t_pair_secs: 0.0,
                    updates_dropped: stats.fault_counts[job].0,
                    updates_decayed: stats.fault_counts[job].1,
                    rounds_skipped: stats.fault_counts[job].2,
                    solo_mean_latency_secs: self
                        .solo_baselines
                        .then(|| crate::broker::solo_mean_latency(arr, self.seed, job)),
                }
            })
            .collect();
        Ok(Report::Sim(RunSummary {
            policy: self.policy,
            capacity,
            seed: self.seed,
            jobs,
            cluster_utilization: stats.total_container_seconds
                / (capacity as f64 * span.max(1e-9)),
            total_container_seconds: stats.total_container_seconds,
            span_secs: span,
            updates_folded: 0,
            preemptions: stats.preemptions,
            wall_secs: wall_start.elapsed().as_secs_f64(),
            crashed: false,
        }))
    }

    /// Wall-driver regimes: the unified multi-job control loop of
    /// `coordinator::live` — a single job is its N = 1 case.
    fn run_live_mode(self) -> Result<Report> {
        let wall = self.mode == Mode::Wall;
        let shards = self.shards;
        if shards == 0 || shards > crate::fusion::shard::BUCKETS {
            return Err(anyhow!(
                "shards must be in 1..={} (the fixed logical-bucket count), got {shards}",
                crate::fusion::shard::BUCKETS
            ));
        }
        if let Some(k) = &self.kill_shard {
            if k.shard >= shards {
                return Err(anyhow!(
                    "kill_shard targets shard {} but the session has {shards} shard(s)",
                    k.shard
                ));
            }
        }
        let backend = self.backend.unwrap_or(match (wall, self.arrivals.len()) {
            (false, _) => PartyBackend::Scripted,
            (true, 1) => PartyBackend::SynthThreads,
            (true, _) => PartyBackend::Scripted,
        });
        if !wall && backend != PartyBackend::Scripted {
            return Err(anyhow!(
                "thread party backends need the real clock: use Session::wall()"
            ));
        }
        if self.arrivals.len() > 1 && backend != PartyBackend::Scripted {
            return Err(anyhow!(
                "multi-job sessions run scripted parties (thread backends are single-job)"
            ));
        }
        if self.resume && self.mq.is_none() && self.data_dir.is_none() {
            return Err(anyhow!(
                "resume needs the MQ the crashed run wrote to: pass it with .on(&mq) \
                 or point .data_dir(..) at its durable log \
                 (a fresh private MQ has no §5.5 state to restore)"
            ));
        }
        if self.mq.is_some() && self.data_dir.is_some() {
            return Err(anyhow!(
                "pass either .on(&mq) or .data_dir(..), not both \
                 (an explicit MQ already decides where the data plane lives)"
            ));
        }
        let capacity = self.capacity.unwrap_or_else(|| self.default_capacity()).max(1);
        let mq = match (&self.mq, &self.data_dir) {
            (Some(mq), _) => Arc::clone(mq),
            (None, Some(dir)) => Arc::new(
                MessageQueue::durable(WalConfig::new(dir).fsync(self.fsync))
                    .map_err(|e| anyhow!("opening durable data plane: {e}"))?,
            ),
            (None, None) => Arc::new(MessageQueue::new()),
        };
        mq.set_telemetry(&self.telemetry);
        let mut engines: Vec<JobEngine> = Vec::with_capacity(self.arrivals.len());
        let mut weights: Vec<Vec<f32>> = Vec::with_capacity(self.arrivals.len());
        for (job, arr) in self.arrivals.iter().enumerate() {
            let mut engine =
                JobEngine::with_faults(job, arr.spec.clone(), &arr.strategy, self.seed, self.faults);
            engine.deferred = true;
            engine.shards = shards;
            engine.set_adaptive(self.adaptive.clone());
            engine.set_telemetry(&self.telemetry, &arr.strategy);
            weights.push(
                engine
                    .fleet
                    .parties
                    .iter()
                    .map(|p| p.dataset_items as f32)
                    .collect(),
            );
            engines.push(engine);
        }
        let params = live::LoopParams {
            arrivals: &self.arrivals,
            capacity,
            admission: self.admission_cfg(),
            policy: self.policy.clone(),
            seed: self.seed,
            dim: self.dim.max(1),
            kill_after_fuses: self.kill_after_fuses,
            shards,
            kill_shard: self.kill_shard,
            resume: self.resume,
            init_override: None,
            sink: self.sink.clone(),
            telemetry: self.telemetry.clone(),
        };
        let summary = match backend {
            PartyBackend::Scripted => {
                let source =
                    ScriptedParties::multi_job(self.seed, self.lr, weights).with_shards(shards);
                if wall {
                    live::session_loop(
                        params,
                        &mq,
                        WallDriver::new(WallClock::new(), source).with_shards(shards),
                        engines,
                        None,
                    )?
                } else {
                    live::session_loop(
                        params,
                        &mq,
                        WallDriver::new(InstantClock::default(), source).with_shards(shards),
                        engines,
                        None,
                    )?
                }
            }
            PartyBackend::SynthThreads => {
                let clock = WallClock::new();
                let source = ThreadParties::synth(
                    &mq,
                    clock.timer,
                    self.seed,
                    self.lr,
                    &weights[0],
                    shards,
                );
                live::session_loop(
                    params,
                    &mq,
                    WallDriver::new(clock, source).with_shards(shards),
                    engines,
                    None,
                )?
            }
            PartyBackend::XlaThreads => live::run_session_xla(
                params,
                &mq,
                engines,
                live::XlaSessionConfig {
                    n_parties: self.arrivals[0].spec.n_parties,
                    minibatches: self.minibatches,
                    alpha: self.alpha,
                    seed: self.seed,
                    lr: self.lr,
                    shards,
                },
            )?,
        };
        Ok(if wall {
            Report::Wall(summary)
        } else {
            Report::Live(summary)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::party::FleetKind;
    use crate::workloads::Workload;

    fn spec(parties: usize, rounds: u32) -> FlJobSpec {
        FlJobSpec::new(
            Workload::mlp_live(),
            FleetKind::ActiveHomogeneous,
            parties,
            rounds,
        )
    }

    #[test]
    fn empty_session_and_bad_knobs_are_rejected() {
        assert!(Session::sim().run().is_err(), "no jobs");
        let mut s = Session::sim().policy("bogus");
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "bad policy");
        let mut s = Session::sim();
        s.job(spec(3, 1), "frobnicate");
        assert!(s.run().is_err(), "bad strategy");
        let mut s = Session::sim().resume(true);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "sim cannot resume");
        let mut s = Session::sim().kill_after_fuses(Some(1));
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "sim has no data plane to kill");
        let mut s = Session::live().backend(PartyBackend::SynthThreads);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "threads need the wall clock");
        let mut s = Session::sim().kill_shard(0, 1, false);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "sim has no shards to kill");
        let mut s = Session::live().shards(0);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "zero shards");
        let mut s = Session::live().shards(crate::fusion::shard::BUCKETS + 1);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "more shards than logical buckets");
        let mut s = Session::live().shards(2).kill_shard(5, 1, false);
        s.job(spec(3, 1), "jit");
        assert!(s.run().is_err(), "kill target beyond the shard count");
        let mut s = Session::live().resume(true); // no .on(&mq)
        s.job(spec(3, 1), "jit");
        assert!(
            s.run().is_err(),
            "resume without the crashed run's MQ has nothing to restore"
        );
    }

    #[test]
    fn sim_session_runs_and_reports() {
        let mut s = Session::sim().seed(3);
        let h = s.job(spec(6, 2), "jit");
        let rep = s.run().expect("sim run");
        assert_eq!(rep.mode_name(), "sim");
        let o = rep.job(h);
        assert_eq!(o.records.len(), 2);
        assert_eq!(o.updates_fused, 12);
        assert_eq!(o.updates_folded, 0, "sim folds nothing for real");
        assert!(o.final_model.is_empty());
        assert!(o.container_seconds > 0.0);
        assert!(!rep.summary().crashed);
    }

    #[test]
    fn live_session_runs_the_real_data_plane() {
        let mut s = Session::live().seed(3).dim(16);
        let h = s.job(spec(4, 2), "jit");
        let rep = s.run().expect("live run");
        assert_eq!(rep.mode_name(), "live");
        let o = rep.job(h);
        assert_eq!(o.records.len(), 2);
        assert_eq!(o.updates_folded, 8, "every update folds exactly once");
        assert_eq!(o.final_model.len(), 16);
    }

    #[test]
    fn job_handles_index_multi_job_reports() {
        let mut s = Session::sim().seed(9).capacity(8);
        let a = s.job_at(spec(3, 1), "jit", 0.0, SloClass::Standard);
        let b = s.job_at(spec(4, 1), "lazy", 0.5, SloClass::Premium);
        let rep = s.run().expect("two jobs");
        assert_eq!(rep.jobs().len(), 2);
        assert_eq!(rep.job(a).parties, 3);
        assert_eq!(rep.job(b).parties, 4);
        assert_eq!(rep.job(b).strategy, "lazy");
        assert_eq!(rep.job(b).class, SloClass::Premium);
    }

    #[test]
    fn events_stream_covers_the_round_lifecycle() {
        let mut s = Session::live().seed(5).dim(8);
        let h = s.job(spec(3, 2), "jit");
        let rx = s.events();
        let rep = s.run().expect("live run");
        let events: Vec<SessionEvent> = rx.try_iter().collect();
        let submitted = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::JobSubmitted { .. }))
            .count();
        let started = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::RoundStarted { .. }))
            .count();
        let fused: Vec<u32> = events
            .iter()
            .filter_map(|e| match e {
                SessionEvent::RoundFused { round, .. } => Some(*round),
                _ => None,
            })
            .collect();
        let folds: u64 = events
            .iter()
            .filter_map(|e| match e {
                SessionEvent::CheckpointWritten { folds, .. } => Some(*folds),
                _ => None,
            })
            .sum();
        assert_eq!(submitted, 1);
        assert_eq!(started, 2);
        assert_eq!(fused, vec![0, 1]);
        assert_eq!(folds, rep.job(h).updates_folded);
        assert!(matches!(
            events.last(),
            Some(SessionEvent::JobFinished { .. })
        ));
    }

    #[test]
    fn schema_lines_flatten_objects_arrays_and_nulls() {
        let v = Json::obj(vec![
            ("b", Json::num(1.0)),
            ("a", Json::str("x")),
            ("c", Json::Arr(vec![Json::obj(vec![("k", Json::Null)])])),
            ("d", Json::Arr(vec![])),
            ("e", Json::Bool(true)),
        ]);
        let lines = json_schema_lines(&v);
        assert_eq!(
            lines,
            vec![
                ".a: str",
                ".b: num",
                ".c[].k: null",
                ".d[]: (empty)",
                ".e: bool",
            ]
        );
    }
}
