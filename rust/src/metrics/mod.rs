//! Evaluation metrics (§6.2): aggregation latency, container-seconds, and
//! projected cost at Azure Container Instances pricing.

use crate::util::json::Json;
use crate::util::stats::{percentile, Summary};

/// §6.2 / Fig 9: container cost per second (Microsoft Azure, 2021).
pub const AZURE_USD_PER_CONTAINER_SECOND: f64 = 0.0002692;

/// Per-round record.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: u32,
    /// Aggregation latency: "time elapsed between the reception of the last
    /// model update and the availability of the aggregated model" (§6.2).
    pub latency_secs: f64,
    /// When the round's last update arrived (virtual secs).
    pub last_arrival_secs: f64,
    /// When the fused model became available.
    pub complete_secs: f64,
}

/// A finished job's measurements.
#[derive(Clone, Debug, Default)]
pub struct JobReport {
    pub strategy: String,
    pub workload: String,
    pub fleet: String,
    pub parties: usize,
    pub rounds: Vec<RoundRecord>,
    /// Aggregation container-seconds from the cluster ledger.
    pub container_seconds: f64,
    /// Ancillary-service container-seconds (MongoDB/Kafka/COS share).
    pub ancillary_seconds: f64,
    /// Aggregator deployments across the job.
    pub deployments: u64,
    /// Updates fused across the job.
    pub updates_fused: u64,
    /// Absolute virtual-time instant the job finished (seconds from
    /// platform start). For jobs admitted at t = 0 this equals the wall
    /// duration; for broker jobs arriving later it includes arrival +
    /// queue time (RunSummary::max_concurrent_jobs relies on this
    /// absolute interpretation).
    pub makespan_secs: f64,
}

impl JobReport {
    /// Total container-seconds (aggregation + ancillary) — the Fig 9 metric.
    pub fn total_container_seconds(&self) -> f64 {
        self.container_seconds + self.ancillary_seconds
    }

    /// Projected cost in USD (Fig 9).
    pub fn cost_usd(&self) -> f64 {
        self.total_container_seconds() * AZURE_USD_PER_CONTAINER_SECOND
    }

    /// Mean aggregation latency over rounds — the Fig 7/8 metric ("reported
    /// numbers … are averaged over all the rounds of the FL job").
    pub fn mean_latency_secs(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.latency_secs).sum::<f64>() / self.rounds.len() as f64
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.rounds.iter().map(|r| r.latency_secs).collect::<Vec<_>>())
    }

    pub fn latency_p95(&self) -> f64 {
        percentile(
            &self.rounds.iter().map(|r| r.latency_secs).collect::<Vec<_>>(),
            95.0,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(&self.strategy)),
            ("workload", Json::str(&self.workload)),
            ("fleet", Json::str(&self.fleet)),
            ("parties", Json::num(self.parties as f64)),
            ("rounds", Json::num(self.rounds.len() as f64)),
            ("mean_latency_secs", Json::num(self.mean_latency_secs())),
            ("latency_p95_secs", Json::num(self.latency_p95())),
            ("container_seconds", Json::num(self.container_seconds)),
            ("ancillary_seconds", Json::num(self.ancillary_seconds)),
            (
                "total_container_seconds",
                Json::num(self.total_container_seconds()),
            ),
            ("cost_usd", Json::num(self.cost_usd())),
            ("deployments", Json::num(self.deployments as f64)),
            ("updates_fused", Json::num(self.updates_fused as f64)),
            ("makespan_secs", Json::num(self.makespan_secs)),
        ])
    }
}

/// Savings of `ours` vs `baseline` in container-seconds (Fig 9 right).
pub fn savings_pct(ours: &JobReport, baseline: &JobReport) -> f64 {
    let b = baseline.total_container_seconds();
    if b <= 0.0 {
        return 0.0;
    }
    (1.0 - ours.total_container_seconds() / b) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cs: f64, latencies: &[f64]) -> JobReport {
        JobReport {
            strategy: "jit".into(),
            workload: "w".into(),
            fleet: "active-homog".into(),
            parties: 10,
            rounds: latencies
                .iter()
                .enumerate()
                .map(|(i, &l)| RoundRecord {
                    round: i as u32,
                    latency_secs: l,
                    last_arrival_secs: 0.0,
                    complete_secs: l,
                })
                .collect(),
            container_seconds: cs,
            ancillary_seconds: 10.0,
            deployments: 3,
            updates_fused: 30,
            makespan_secs: 100.0,
        }
    }

    #[test]
    fn cost_projection_uses_azure_rate() {
        let r = report(90.0, &[1.0]);
        assert!((r.total_container_seconds() - 100.0).abs() < 1e-12);
        assert!((r.cost_usd() - 0.02692).abs() < 1e-9);
    }

    #[test]
    fn latency_aggregates() {
        let r = report(0.0, &[1.0, 2.0, 3.0, 4.0]);
        assert!((r.mean_latency_secs() - 2.5).abs() < 1e-12);
        assert!(r.latency_p95() > 3.5);
        assert_eq!(r.latency_summary().n, 4);
        assert_eq!(report(0.0, &[]).mean_latency_secs(), 0.0);
    }

    #[test]
    fn savings_formula() {
        let jit = report(40.0, &[1.0]); // total 50
        let eager = report(190.0, &[1.0]); // total 200
        assert!((savings_pct(&jit, &eager) - 75.0).abs() < 1e-9);
        let zero = report(0.0, &[1.0]);
        let mut z2 = zero.clone();
        z2.ancillary_seconds = 0.0;
        z2.container_seconds = 0.0;
        assert_eq!(savings_pct(&jit, &z2), 0.0);
    }

    #[test]
    fn json_export_roundtrips() {
        let r = report(40.0, &[1.0, 2.0]);
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.print()).unwrap();
        assert_eq!(parsed.get("strategy").as_str(), Some("jit"));
        assert_eq!(parsed.get("parties").as_u64(), Some(10));
        assert!((parsed.get("cost_usd").as_f64().unwrap() - r.cost_usd()).abs() < 1e-9);
    }
}
