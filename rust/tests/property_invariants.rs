//! Property tests over whole-platform scenarios: invariants that must hold
//! for *any* randomly drawn job configuration, via the in-tree prop
//! harness (util::prop).

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::run_scenario;
use fljit::party::FleetKind;
use fljit::util::prop;
use fljit::workloads::Workload;

fn random_spec(g: &mut prop::Gen) -> FlJobSpec {
    let workloads = [
        Workload::cifar100_effnet(),
        Workload::rvlcdip_vgg16(),
        Workload::inat_inception(),
    ];
    let fleets = [
        FleetKind::ActiveHomogeneous,
        FleetKind::ActiveHeterogeneous,
        FleetKind::IntermittentHeterogeneous,
    ];
    let w = workloads[g.usize(0, 2).min(2)].clone();
    let fleet = fleets[g.usize(0, 2).min(2)];
    let parties = g.usize(2, 60);
    let rounds = g.usize(1, 6) as u32;
    let mut spec = FlJobSpec::new(w, fleet, parties, rounds);
    spec.t_wait_secs = g.f64(60.0, 600.0);
    spec.report_prob = g.f64(0.0, 1.0);
    spec
}

#[test]
fn every_strategy_completes_every_round_and_fuses_everything() {
    prop::check("completion", 24, |g| {
        let spec = random_spec(g);
        let strat = *g.rng.choose(&["jit", "batched", "eager-serverless", "eager-ao", "lazy"]);
        let r = run_scenario(&spec, strat, g.rng.next_u64());
        fljit::prop_assert!(
            r.rounds.len() == spec.rounds as usize,
            "{strat}: {} of {} rounds completed ({} parties, {})",
            r.rounds.len(),
            spec.rounds,
            spec.n_parties,
            spec.fleet_kind.name()
        );
        fljit::prop_assert!(
            r.updates_fused == (spec.n_parties as u64) * spec.rounds as u64,
            "{strat}: fused {} != {}",
            r.updates_fused,
            spec.n_parties * spec.rounds as usize
        );
        Ok(())
    });
}

#[test]
fn latencies_nonnegative_and_rounds_ordered() {
    prop::check("latency-sanity", 16, |g| {
        let spec = random_spec(g);
        let strat = *g.rng.choose(&["jit", "batched", "eager-serverless"]);
        let r = run_scenario(&spec, strat, g.rng.next_u64());
        let mut prev_complete = f64::NEG_INFINITY;
        for rec in &r.rounds {
            fljit::prop_assert!(
                rec.latency_secs >= 0.0,
                "negative latency {} in round {}",
                rec.latency_secs,
                rec.round
            );
            fljit::prop_assert!(
                rec.complete_secs >= rec.last_arrival_secs - 1e-9,
                "round {} completed before its last arrival",
                rec.round
            );
            fljit::prop_assert!(
                rec.complete_secs > prev_complete,
                "rounds complete out of order"
            );
            prev_complete = rec.complete_secs;
        }
        Ok(())
    });
}

#[test]
fn container_seconds_bounded_below_by_pure_work() {
    // cs can never be less than the fusion work itself: N·rounds·item.
    prop::check("cs-lower-bound", 16, |g| {
        let spec = random_spec(g);
        let strat = *g.rng.choose(&["jit", "batched", "eager-serverless", "eager-ao"]);
        let r = run_scenario(&spec, strat, g.rng.next_u64());
        let item = spec.workload.t_pair / 2.0; // C_agg = 2
        let work = spec.n_parties as f64 * spec.rounds as f64 * item;
        fljit::prop_assert!(
            r.container_seconds >= work * 0.99,
            "{strat}: cs {} below pure work {}",
            r.container_seconds,
            work
        );
        Ok(())
    });
}

#[test]
fn jit_never_costlier_than_always_on() {
    prop::check("jit<=ao", 12, |g| {
        let spec = random_spec(g);
        let seed = g.rng.next_u64();
        let jit = run_scenario(&spec, "jit", seed);
        let ao = run_scenario(&spec, "eager-ao", seed);
        fljit::prop_assert!(
            jit.total_container_seconds() <= ao.total_container_seconds() * 1.01,
            "jit {} > ao {} ({} parties, {})",
            jit.total_container_seconds(),
            ao.total_container_seconds(),
            spec.n_parties,
            spec.fleet_kind.name()
        );
        Ok(())
    });
}

#[test]
fn deployments_bounded_by_updates_plus_fleet() {
    // no strategy may deploy more containers than one per update plus the
    // always-on fleet (sanity bound on deployment storms)
    prop::check("deployment-bound", 16, |g| {
        let spec = random_spec(g);
        let strat = *g.rng.choose(&["jit", "batched", "eager-serverless", "eager-ao", "lazy"]);
        let r = run_scenario(&spec, strat, g.rng.next_u64());
        let bound = (spec.n_parties * spec.rounds as usize
            + spec.workload.n_agg(spec.n_parties) as usize
            + spec.rounds as usize) as u64;
        fljit::prop_assert!(
            r.deployments <= bound,
            "{strat}: {} deployments > bound {bound}",
            r.deployments
        );
        Ok(())
    });
}
