//! Integration: every aggregation strategy must produce the *same fused
//! model* — the design options of §3 trade cost and latency, never
//! correctness. We fold the same set of updates in each strategy's
//! characteristic order/grouping through the pure-Rust fusion engine and
//! pin the results together (and, transitively via pytest + the runtime
//! round-trip test, to the Pallas kernels).

use fljit::fusion::{tree_reduce, weighted_mean, Aggregator};
use fljit::model::{ModelSpec, ModelUpdate};
use fljit::util::rng::Rng;

fn make_updates(n: usize, dim: usize, seed: u64) -> Vec<ModelUpdate> {
    let spec = ModelSpec::new("m", vec![("l", dim)]);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let w = rng.range_f64(0.5, 8.0) as f32;
            ModelUpdate::random(&spec, &mut rng, w)
        })
        .collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!((x - y).abs() < tol, "{what} elem {i}: {x} vs {y}");
    }
}

#[test]
fn all_aggregation_orders_agree() {
    let updates = make_updates(23, 2048, 99);
    let dim = 2048;

    // Eager (always-on / serverless): one-at-a-time in arrival order.
    let mut eager = Aggregator::new(dim);
    for u in &updates {
        eager.add(&u.data, u.weight);
    }

    // Batched: fold in batches of 5, each batch into a partial that is
    // checkpointed and restored (fresh deployment per batch).
    let mut batched = Aggregator::new(dim);
    for chunk in updates.chunks(5) {
        // restore from "checkpoint"
        let mut partial =
            Aggregator::from_parts(batched.acc.clone(), batched.weight, batched.n_merged);
        for u in chunk {
            partial.add(&u.data, u.weight);
        }
        batched = partial; // checkpoint back
    }

    // Lazy / JIT with N_agg parallel shards: tree reduction.
    let jit = tree_reduce(&updates, 4);

    // One-shot weighted mean (the oracle).
    let views: Vec<&[f32]> = updates.iter().map(|u| u.data.as_slice()).collect();
    let ws: Vec<f32> = updates.iter().map(|u| u.weight).collect();
    let oracle = weighted_mean(&views, &ws);

    assert_close(&eager.acc, &oracle, 1e-3, "eager vs oracle");
    assert_close(&batched.acc, &oracle, 1e-3, "batched vs oracle");
    assert_close(&jit.acc, &oracle, 1e-3, "jit/tree vs oracle");
    assert_eq!(eager.n_merged, 23);
    assert_eq!(batched.n_merged, 23);
    assert_eq!(jit.n_merged, 23);
}

#[test]
fn preemption_checkpoint_mid_round_is_lossless() {
    // JIT preemption (§5.5): partial aggregate checkpointed to the MQ and
    // resumed by a later deployment must equal the uninterrupted fold.
    let updates = make_updates(16, 1024, 5);
    let mq = fljit::mq::MessageQueue::new();
    let slot = fljit::mq::checkpoint_slot(0, 3);

    let mut uninterrupted = Aggregator::new(1024);
    for u in &updates {
        uninterrupted.add(&u.data, u.weight);
    }

    // first deployment folds 7, preempted, checkpoints
    let mut first = Aggregator::new(1024);
    for u in &updates[..7] {
        first.add(&u.data, u.weight);
    }
    mq.save_checkpoint(
        &slot,
        fljit::mq::CheckpointState {
            acc: Some(first.acc.clone()),
            weight: first.weight,
            n_merged: first.n_merged,
            consumed_to: 7,
            saved_at: 0,
        },
    );

    // resumed deployment restores and finishes
    let ckpt = mq.load_checkpoint(&slot).expect("checkpoint");
    let mut resumed = Aggregator::from_parts(ckpt.acc.unwrap(), ckpt.weight, ckpt.n_merged);
    for u in &updates[ckpt.consumed_to..] {
        resumed.add(&u.data, u.weight);
    }
    assert_close(&uninterrupted.acc, &resumed.acc, 1e-4, "preempted vs straight");
    assert!(mq.clear_checkpoint(&slot));
}

#[test]
fn fedprox_consistent_across_fold_orders() {
    let updates = make_updates(9, 512, 41);
    let spec = ModelSpec::new("g", vec![("l", 512)]);
    let mut rng = Rng::new(123);
    let global = ModelUpdate::random(&spec, &mut rng, 1.0);
    let alg = fljit::fusion::Algorithm::FedProx { mu: 0.25 };

    let mut stream = Aggregator::new(512);
    for u in &updates {
        stream.add(&u.data, u.weight);
    }
    let a = stream.finalize(alg, Some(&global.data));

    let tree = tree_reduce(&updates, 3);
    let b = tree.finalize(alg, Some(&global.data));
    assert_close(&a, &b, 1e-3, "fedprox stream vs tree");
}
