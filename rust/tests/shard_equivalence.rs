//! Sharded/unsharded equivalence **through the `Session` façade**: an
//! L1 aggregator tree of any width must be a pure performance shape —
//! `Session::…().shards(n)` has to produce the *same* report and the
//! *same* published models, bit for bit, as the single-fold plane.
//!
//! The data plane makes that structural rather than coincidental: every
//! shard folds its parties into fixed logical buckets
//! (`fusion::shard::BUCKETS` contiguous party-id ranges, independent of
//! the shard count), and the root combines bucket partials in ascending
//! bucket order — so the floating-point operation sequence is a
//! function of the party partition only, never of how many shards
//! happened to host it. These tests pin that claim across strategies,
//! fleet kinds, both deterministic regimes, fleet fault injection, and
//! single-shard kill/resume (including a torn mid-checkpoint death).

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::{JobOutcome, Session};
use fljit::party::{FleetFaults, FleetKind};
use fljit::workloads::Workload;

/// The swept tree widths: the degenerate tree (1), an even split (2)
/// and a width that leaves several shards empty at small party counts
/// (7), per the acceptance grid.
const SHARD_COUNTS: [usize; 3] = [1, 2, 7];

fn spec(fleet: FleetKind, parties: usize, rounds: u32) -> FlJobSpec {
    FlJobSpec::new(Workload::cifar100_effnet(), fleet, parties, rounds)
}

/// One live run; `shards == 0` leaves the knob untouched (the unsharded
/// baseline plane every sharded run is compared against).
fn run_live(
    strategy: &str,
    fleet: FleetKind,
    parties: usize,
    rounds: u32,
    seed: u64,
    faults: FleetFaults,
    shards: usize,
) -> JobOutcome {
    let mut s = Session::live().seed(seed).dim(48).faults(faults);
    if shards > 0 {
        s = s.shards(shards);
    }
    let h = s.job(spec(fleet, parties, rounds), strategy);
    let rep = s
        .run()
        .unwrap_or_else(|e| panic!("{strategy}/{fleet:?}/shards={shards}: {e:#}"));
    assert!(
        !rep.summary().crashed,
        "{strategy}/{fleet:?}/shards={shards}: unexpected crash"
    );
    rep.job(h).clone()
}

/// Bit-level outcome comparison: the whole round-record sequence, every
/// counter, and each final-model lane compared on raw bits (an `==` on
/// f32 would let -0.0 ≡ 0.0 slip through).
fn assert_outcomes_identical(a: &JobOutcome, b: &JobOutcome, label: &str) {
    assert_outcomes_identical_with_extra_folds(a, b, 0, label)
}

/// Same, but `b` is allowed exactly `extra` additional real folds — a
/// torn mid-checkpoint shard death re-folds the one update whose
/// checkpoint write was lost, which is honest extra work, not drift.
fn assert_outcomes_identical_with_extra_folds(
    a: &JobOutcome,
    b: &JobOutcome,
    extra: u64,
    label: &str,
) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: round count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round, "{label}: round index");
        assert_eq!(
            x.latency_secs.to_bits(),
            y.latency_secs.to_bits(),
            "{label} round {}: latency {} vs {}",
            x.round,
            x.latency_secs,
            y.latency_secs
        );
        assert_eq!(
            x.last_arrival_secs.to_bits(),
            y.last_arrival_secs.to_bits(),
            "{label} round {}: last arrival",
            x.round
        );
        assert_eq!(
            x.complete_secs.to_bits(),
            y.complete_secs.to_bits(),
            "{label} round {}: completion time",
            x.round
        );
    }
    assert_eq!(a.updates_fused, b.updates_fused, "{label}: fuse count");
    assert_eq!(
        a.updates_folded + extra,
        b.updates_folded,
        "{label}: fold count"
    );
    assert_eq!(a.deployments, b.deployments, "{label}: deployments");
    assert_eq!(
        (a.updates_dropped, a.updates_decayed, a.rounds_skipped),
        (b.updates_dropped, b.updates_decayed, b.rounds_skipped),
        "{label}: degradation counters"
    );
    assert_eq!(
        a.final_model.len(),
        b.final_model.len(),
        "{label}: model dimension"
    );
    for (i, (x, y)) in a.final_model.iter().zip(&b.final_model).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: final model lane {i}: {x} vs {y}"
        );
    }
}

/// Dropout churn + heavy-tailed stragglers with a reporting deadline —
/// the hostile cell the faulted equivalence pins run under.
fn hostile_faults() -> FleetFaults {
    FleetFaults {
        dropout_prob: 0.2,
        rejoin_after: 1,
        straggler_prob: 0.3,
        straggler_alpha: 1.2,
        upload_tail_sigma: 0.3,
        straggler_cutoff_secs: Some(Workload::cifar100_effnet().base_epoch_secs * 2.0),
        ..FleetFaults::default()
    }
}

/// Every §3 strategy, every swept tree width: the sharded live plane is
/// bit-identical to the unsharded one.
#[test]
fn every_strategy_is_bit_identical_across_shard_counts() {
    for (i, strategy) in [
        "jit",
        "batched",
        "eager-serverless",
        "eager-ao",
        "lazy",
        "async-stale",
    ]
    .iter()
    .enumerate()
    {
        let seed = 0x5A0 + i as u64;
        let flat = run_live(
            strategy,
            FleetKind::ActiveHomogeneous,
            10,
            2,
            seed,
            FleetFaults::none(),
            0,
        );
        for n in SHARD_COUNTS {
            let sharded = run_live(
                strategy,
                FleetKind::ActiveHomogeneous,
                10,
                2,
                seed,
                FleetFaults::none(),
                n,
            );
            assert_outcomes_identical(&flat, &sharded, &format!("{strategy} shards={n}"));
        }
    }
}

/// The other fleet kinds (heterogeneous speeds, intermittent
/// availability windows) reorder arrivals — the tree must not care.
#[test]
fn every_fleet_kind_is_bit_identical_across_shard_counts() {
    for (i, fleet) in [
        FleetKind::ActiveHomogeneous,
        FleetKind::ActiveHeterogeneous,
        FleetKind::IntermittentHeterogeneous,
    ]
    .into_iter()
    .enumerate()
    {
        let seed = 0x5B0 + i as u64;
        let flat = run_live("jit", fleet, 8, 2, seed, FleetFaults::none(), 0);
        for n in SHARD_COUNTS {
            let sharded = run_live("jit", fleet, 8, 2, seed, FleetFaults::none(), n);
            assert_outcomes_identical(&flat, &sharded, &format!("{fleet:?} shards={n}"));
        }
    }
}

/// Fault injection (dropout, stragglers, deadline cuts) shrinks and
/// reorders each round's arrivals; the bucket partition keeps the fold
/// order a function of *which* parties reported, so the sharded plane
/// stays bit-identical under the hostile fleet — `async-stale`'s
/// self-scheduled late deliveries included.
#[test]
fn hostile_faults_stay_bit_identical_across_shard_counts() {
    for (i, strategy) in ["jit", "batched", "async-stale"].iter().enumerate() {
        let seed = 0x5C0 + i as u64;
        let flat = run_live(
            strategy,
            FleetKind::ActiveHomogeneous,
            10,
            3,
            seed,
            hostile_faults(),
            0,
        );
        for n in SHARD_COUNTS {
            let sharded = run_live(
                strategy,
                FleetKind::ActiveHomogeneous,
                10,
                3,
                seed,
                hostile_faults(),
                n,
            );
            assert_outcomes_identical(
                &flat,
                &sharded,
                &format!("{strategy}+faults shards={n}"),
            );
        }
    }
}

/// Sim has no data plane to shard: the knob must be accepted (API
/// symmetry with live/wall) and must change nothing.
#[test]
fn sim_accepts_the_shards_knob_and_ignores_it() {
    let run = |shards: usize| {
        let mut s = Session::sim().seed(0x5D1);
        if shards > 0 {
            s = s.shards(shards);
        }
        let h = s.job(spec(FleetKind::ActiveHeterogeneous, 10, 3), "jit");
        let rep = s.run().expect("sim run");
        rep.job(h).clone()
    };
    let flat = run(0);
    for n in SHARD_COUNTS {
        let sharded = run(n);
        assert_outcomes_identical(&flat, &sharded, &format!("sim shards={n}"));
    }
}

/// More shards than parties: with 3 parties on a 7-wide tree most
/// shards own buckets no party maps to, and under dropout whole shards
/// can see zero updates in a round. Empty shards must be skipped by the
/// root fold, not wedge it — and the result is still bit-identical.
#[test]
fn empty_shards_do_not_wedge_the_root_fold() {
    let flat = run_live(
        "jit",
        FleetKind::ActiveHomogeneous,
        3,
        2,
        0x5E2,
        FleetFaults::none(),
        0,
    );
    let sharded = run_live(
        "jit",
        FleetKind::ActiveHomogeneous,
        3,
        2,
        0x5E2,
        FleetFaults::none(),
        7,
    );
    assert_outcomes_identical(&flat, &sharded, "3 parties on 7 shards");

    // and with dropout churn shrinking rounds further
    let flat = run_live(
        "jit",
        FleetKind::ActiveHomogeneous,
        4,
        3,
        0x5E3,
        hostile_faults(),
        0,
    );
    let sharded = run_live(
        "jit",
        FleetKind::ActiveHomogeneous,
        4,
        3,
        0x5E3,
        hostile_faults(),
        7,
    );
    assert_outcomes_identical(&flat, &sharded, "4 faulty parties on 7 shards");
}

/// §5.5 per shard: kill one L1 shard mid-round and the round still
/// completes — the replacement shard revives from its *own* WAL
/// checkpoint slot and replays its own topic remainder while the
/// sibling shards' fold states are never rebuilt. The published model
/// stream must be bit-identical to the never-killed run, and the
/// telemetry must show exactly one shard restart.
#[test]
fn single_shard_kill_revives_from_its_checkpoint_bit_identical() {
    shard_kill_case(false, 0x5F1);
}

/// The same, dying *mid-checkpoint*: the fatal fold is applied in
/// memory but its checkpoint write is lost (torn), so the revived
/// shard's slot is one fold behind and the replay must re-fold that
/// update from the shard's topic log.
#[test]
fn mid_checkpoint_shard_kill_replays_the_torn_fold_bit_identical() {
    shard_kill_case(true, 0x5F2);
}

fn shard_kill_case(torn: bool, seed: u64) {
    use fljit::mq::{self, MessageQueue};
    use fljit::telemetry::{export, Registry};
    use std::sync::Arc;

    let session = |mq: &Arc<MessageQueue>,
                   kill: Option<(usize, u64, bool)>,
                   tel: &Registry| {
        let mut s = Session::live()
            .seed(seed)
            .dim(48)
            .on(mq)
            .shards(3)
            .telemetry(tel);
        if let Some((shard, after, torn)) = kill {
            s = s.kill_shard(shard, after, torn);
        }
        let h = s.job(spec(FleetKind::ActiveHomogeneous, 9, 3), "jit");
        let rep = s.run().expect("sharded session run");
        (rep, h)
    };

    let mq_ref = Arc::new(MessageQueue::new());
    let (full, hf) = session(&mq_ref, None, &Registry::disabled());
    assert!(!full.summary().crashed);
    let published = mq_ref.end_offset(&mq::model_topic(0));
    assert!(published > 0, "the reference run must publish models");

    let tel = Registry::enabled();
    let mq_kill = Arc::new(MessageQueue::new());
    let (killed, hk) = session(&mq_kill, Some((1, 2, torn)), &tel);
    // a single-shard death is NOT a session crash: the siblings keep
    // folding and the replacement shard resumes within the same round
    assert!(
        !killed.summary().crashed,
        "a shard kill must be absorbed, not crash the session"
    );

    let lines = export::metric_lines(&tel);
    assert!(
        lines.iter().any(|l| l.contains("shard_kills_total")),
        "the injected shard kill must be counted: {lines:?}"
    );
    assert!(
        lines.iter().any(|l| l.contains("shard_restarts_total")),
        "the dead shard must revive from its checkpoint: {lines:?}"
    );

    assert_eq!(
        mq_kill.end_offset(&mq::model_topic(0)),
        published,
        "the shard-killed run must publish every round"
    );
    for round in 0..published {
        let a = mq_ref.fetch(&mq::model_topic(0), round, 1);
        let b = mq_kill.fetch(&mq::model_topic(0), round, 1);
        let (a, b) = (a[0].payload.data().unwrap(), b[0].payload.data().unwrap());
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "round {round} lane {i}: {x} vs {y} (torn={torn})"
            );
        }
    }
    assert_outcomes_identical_with_extra_folds(
        full.job(hf),
        killed.job(hk),
        torn as u64,
        &format!("shard kill torn={torn}"),
    );
}
