//! The `Session` façade's public contract:
//!
//! 1. **Report JSON schema stability** — `Report::to_json()` exposes one
//!    key set across the sim / live / broker-trace variants, pinned by a
//!    golden file. Values change run to run; the *shape* must not,
//!    because downstream tooling (BENCH_*.json consumers, EXPERIMENTS.md
//!    tables) parses these dumps. Regenerate with
//!    `UPDATE_GOLDEN=1 cargo test -q --test session_api`.
//! 2. **Event-stream determinism** — `Session::events()` yields a
//!    bit-identical `SessionEvent` sequence for the same (mode, jobs,
//!    seed) in the instant-clock regimes.
//! 3. **Crash + resume through the façade** — the §5.5 story driven
//!    entirely through `Session` knobs (`kill_after_fuses`, `.on(mq)`,
//!    `.resume(true)`), with the crash visible on the event stream.

use std::path::PathBuf;
use std::sync::Arc;

use fljit::broker::workload::{JobArrival, JobTrace};
use fljit::broker::SloClass;
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::{json_schema_lines, Report, Session, SessionEvent};
use fljit::mq::MessageQueue;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

fn spec(parties: usize, rounds: u32) -> FlJobSpec {
    FlJobSpec::new(
        Workload::mlp_live(),
        FleetKind::ActiveHomogeneous,
        parties,
        rounds,
    )
}

fn two_job_trace() -> JobTrace {
    let arrival = |i: usize, at: f64, parties: usize| {
        let mut s = spec(parties, 2);
        s.name = format!("t{i}");
        JobArrival {
            at_secs: at,
            spec: s,
            strategy: "jit".to_string(),
            class: SloClass::Standard,
        }
    };
    JobTrace::from_arrivals(vec![arrival(0, 0.0, 3), arrival(1, 0.5, 4)])
}

// ---------------------------------------------------------------------------
// 1. Report JSON schema golden
// ---------------------------------------------------------------------------

fn schema_section(name: &str, rep: &Report) -> String {
    let mut out = format!("# {name}\n");
    for line in json_schema_lines(&rep.to_json()) {
        out.push_str(&line);
        out.push('\n');
    }
    out
}

#[test]
fn report_json_schema_is_pinned_by_golden_file() {
    let sim_single = {
        let mut s = Session::sim().seed(7);
        s.job(spec(4, 2), "jit");
        s.run().expect("sim single")
    };
    let sim_trace_solo = Session::sim()
        .trace(&two_job_trace())
        .capacity(16)
        .seed(7)
        .solo_baselines(true)
        .run()
        .expect("sim trace");
    let live_single = {
        let mut s = Session::live().seed(7).dim(8);
        s.job(spec(4, 2), "jit");
        s.run().expect("live single")
    };
    let live_trace = Session::live()
        .trace(&two_job_trace())
        .capacity(16)
        .seed(7)
        .dim(8)
        .run()
        .expect("live trace");

    let actual = [
        schema_section("sim-single", &sim_single),
        schema_section("sim-trace-solo", &sim_trace_solo),
        schema_section("live-single", &live_single),
        schema_section("live-trace", &live_trace),
    ]
    .join("\n");

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/report_schema.golden.txt");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, &actual).expect("write golden");
        return;
    }
    let golden = std::fs::read_to_string(&path).expect("golden file present");
    assert_eq!(
        golden.trim(),
        actual.trim(),
        "Report::to_json schema drifted from {path:?}; if the change is \
         deliberate, regenerate with UPDATE_GOLDEN=1 and review the diff"
    );
}

// ---------------------------------------------------------------------------
// 2. Event-stream determinism
// ---------------------------------------------------------------------------

fn live_trace_events(seed: u64) -> Vec<SessionEvent> {
    let mut s = Session::live()
        .trace(&two_job_trace())
        .capacity(8)
        .seed(seed)
        .dim(8);
    let rx = s.events();
    s.run().expect("live trace run");
    rx.try_iter().collect()
}

fn sim_events(seed: u64) -> Vec<SessionEvent> {
    let mut s = Session::sim().seed(seed);
    s.job(spec(4, 2), "jit");
    let rx = s.events();
    s.run().expect("sim run");
    rx.try_iter().collect()
}

#[test]
fn event_ordering_is_deterministic_per_seed() {
    let a = live_trace_events(0x5E55);
    let b = live_trace_events(0x5E55);
    assert!(!a.is_empty());
    assert_eq!(a, b, "live event stream must be a function of the seed");

    let c = sim_events(0x5E55);
    let d = sim_events(0x5E55);
    assert!(!c.is_empty());
    assert_eq!(c, d, "sim event stream must be a function of the seed");

    // a different seed shifts timings, so the streams must differ
    let e = live_trace_events(0x5E56);
    assert_ne!(a, e, "seed must influence the event stream");
}

#[test]
fn event_stream_respects_the_job_lifecycle() {
    let events = live_trace_events(0x5E57);
    for job in 0..2usize {
        let idx = |pred: &dyn Fn(&SessionEvent) -> bool| {
            events.iter().position(|e| pred(e)).unwrap_or(usize::MAX)
        };
        let submitted = idx(&|e| matches!(e, SessionEvent::JobSubmitted { job: j, .. } if *j == job));
        let admitted = idx(&|e| matches!(e, SessionEvent::JobAdmitted { job: j, .. } if *j == job));
        let started = idx(&|e| matches!(e, SessionEvent::RoundStarted { job: j, round: 0, .. } if *j == job));
        let finished = idx(&|e| matches!(e, SessionEvent::JobFinished { job: j, .. } if *j == job));
        assert!(
            submitted < admitted && admitted < started && started < finished,
            "job {job}: lifecycle order (submitted {submitted} < admitted \
             {admitted} < started {started} < finished {finished})"
        );
    }
    // every fold is accounted for on the stream: 3·2 + 4·2 updates
    let folds: u64 = events
        .iter()
        .filter_map(|e| match e {
            SessionEvent::CheckpointWritten { folds, .. } => Some(*folds),
            _ => None,
        })
        .sum();
    assert_eq!(folds, 14);
}

// ---------------------------------------------------------------------------
// 3. Crash + resume, entirely through Session knobs
// ---------------------------------------------------------------------------

#[test]
fn killed_session_streams_crash_and_resume_restores_bit_identical_models() {
    let run = |mq: &Arc<MessageQueue>, kill: Option<u64>, resume: bool| {
        let mut s = Session::live()
            .seed(11)
            .dim(16)
            .on(mq)
            .kill_after_fuses(kill)
            .resume(resume);
        let h = s.job(spec(4, 2), "jit");
        let rx = s.events();
        let rep = s.run().expect("session run");
        let events: Vec<SessionEvent> = rx.try_iter().collect();
        (rep, h, events)
    };

    let mq_full = Arc::new(MessageQueue::new());
    let (full, hf, full_events) = run(&mq_full, None, false);
    assert!(!full.summary().crashed);
    assert!(!full_events
        .iter()
        .any(|e| matches!(e, SessionEvent::Crashed { .. })));

    let mq_kill = Arc::new(MessageQueue::new());
    let (dead, _, dead_events) = run(&mq_kill, Some(3), false);
    assert!(dead.summary().crashed);
    assert!(
        matches!(dead_events.last(), Some(SessionEvent::Crashed { .. })),
        "the crash must be the final event on the stream"
    );

    let (resumed, hr, _) = run(&mq_kill, None, true);
    assert!(!resumed.summary().crashed);
    assert_eq!(
        resumed.job(hr).final_model,
        full.job(hf).final_model,
        "§5.5: resume from the MQ must reproduce the uninterrupted model bit-for-bit"
    );
    assert_eq!(
        dead.single().updates_folded + resumed.single().updates_folded,
        full.single().updates_folded,
        "every update folds exactly once across the two incarnations"
    );
}
