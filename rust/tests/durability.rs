//! Durable data plane, end to end: the segmented mmap log under the MQ
//! must make a live session's state survive real process death.
//!
//! 1. **Mem ≡ Disk** — the same session run on the in-memory log and on
//!    a durable `--data-dir` log reports bit-identical models, folds and
//!    (virtual-clock) latencies. Durability is a side-channel, never a
//!    semantic change.
//! 2. **Kill + reopen resume** — a `kill_after_fuses` crash on a durable
//!    dir, then a resume through a *fresh* `MessageQueue` replayed from
//!    that dir (no shared in-memory state), reproduces the uninterrupted
//!    model bit-for-bit. This is §5.5 across an aggregator incarnation
//!    boundary instead of a shared `Arc`.
//! 3. **Trace re-admission across reopen** — a multi-job broker trace
//!    killed mid-flight resumes from disk with still-queued jobs
//!    re-admitted from the persisted trace, every job finishing.
//! 4. **Real `kill -9`** (unix only) — a wall-paced subprocess run is
//!    SIGKILLed mid-round; `fljit recover` reads the torn log and a
//!    `--resume` run converges to the reference run's model CRCs.

use std::path::PathBuf;
use std::sync::Arc;

use fljit::broker::workload::{JobArrival, JobTrace};
use fljit::broker::SloClass;
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::Session;
use fljit::mq::{self, MessageQueue};
use fljit::party::FleetKind;
use fljit::wal::WalConfig;
use fljit::workloads::Workload;

fn spec(parties: usize, rounds: u32) -> FlJobSpec {
    FlJobSpec::new(
        Workload::mlp_live(),
        FleetKind::ActiveHomogeneous,
        parties,
        rounds,
    )
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fljit_dur_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn two_job_trace() -> JobTrace {
    let arrival = |i: usize, at: f64, parties: usize| {
        let mut s = spec(parties, 2);
        s.name = format!("t{i}");
        JobArrival {
            at_secs: at,
            spec: s,
            strategy: "jit".to_string(),
            class: SloClass::Standard,
        }
    };
    JobTrace::from_arrivals(vec![arrival(0, 0.0, 3), arrival(1, 0.5, 4)])
}

// ---------------------------------------------------------------------------
// 1. Mem ≡ Disk
// ---------------------------------------------------------------------------

#[test]
fn disk_backed_session_reports_bit_identical_to_memory() {
    let dir = tmp("memdisk");
    let run = |data: Option<&PathBuf>| {
        let mut s = Session::live().seed(11).dim(16);
        if let Some(d) = data {
            s = s.data_dir(d);
        }
        let h = s.job(spec(4, 3), "jit");
        (s.run().expect("session run"), h)
    };
    let (mem, hm) = run(None);
    let (disk, hd) = run(Some(&dir));
    let (m, d) = (mem.job(hm), disk.job(hd));
    assert_eq!(
        m.final_model, d.final_model,
        "LogKind::Disk must not change a single model bit"
    );
    assert_eq!(m.updates_folded, d.updates_folded);
    assert_eq!(m.deployments, d.deployments);
    assert_eq!(m.records.len(), d.records.len());
    for (a, b) in m.records.iter().zip(&d.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            a.latency_secs.to_bits(),
            b.latency_secs.to_bits(),
            "virtual-clock latencies are deterministic, WAL writes cost no virtual time"
        );
    }
    // the run's whole model stream is on disk: reopening the dir replays
    // one message per completed round into the model topic
    let q = MessageQueue::durable(WalConfig::new(&dir)).expect("reopen");
    assert_eq!(q.end_offset(&mq::model_topic(0)), m.records.len());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 2. Kill + reopen resume (fresh MQ incarnation from the same dir)
// ---------------------------------------------------------------------------

#[test]
fn killed_durable_session_resumes_bit_identical_across_reopen() {
    let dir = tmp("killreopen");
    let run = |data: Option<&PathBuf>, kill: Option<u64>, resume: bool| {
        let mut s = Session::live()
            .seed(11)
            .dim(16)
            .kill_after_fuses(kill)
            .resume(resume);
        if let Some(d) = data {
            s = s.data_dir(d);
        }
        let h = s.job(spec(4, 2), "jit");
        (s.run().expect("session run"), h)
    };
    // uninterrupted reference on the in-memory log
    let (full, hf) = run(None, None, false);
    assert!(!full.summary().crashed);
    // crash a durable run mid-round; its MQ incarnation dies with it
    let (dead, _) = run(Some(&dir), Some(3), false);
    assert!(dead.summary().crashed);
    // resume builds a brand-new MQ replayed from the dir — the only
    // thing the two incarnations share is the on-disk log
    let (resumed, hr) = run(Some(&dir), None, true);
    assert!(!resumed.summary().crashed);
    assert_eq!(
        resumed.job(hr).final_model,
        full.job(hf).final_model,
        "§5.5 across a process-equivalent boundary: disk replay must \
         reproduce the uninterrupted model bit-for-bit"
    );
    assert_eq!(
        dead.single().updates_folded + resumed.single().updates_folded,
        full.single().updates_folded,
        "every update folds exactly once across the two incarnations"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 3. Multi-job trace: queued jobs re-admitted across reopen
// ---------------------------------------------------------------------------

#[test]
fn trace_resume_readmits_queued_jobs_across_reopen() {
    let dir = tmp("tracereopen");
    let run = |data: Option<&PathBuf>, kill: Option<u64>, resume: bool| {
        let mut s = Session::live()
            .trace(&two_job_trace())
            .capacity(8)
            .seed(7)
            .dim(8)
            .kill_after_fuses(kill)
            .resume(resume);
        if let Some(d) = data {
            s = s.data_dir(d);
        }
        s.run().expect("trace run")
    };
    let full = run(None, None, false);
    // kill after the very first fuse: job 1 is still queued or barely
    // started — the resume must re-admit it from the persisted trace
    let dead = run(Some(&dir), Some(1), false);
    assert!(dead.summary().crashed);
    let resumed = run(Some(&dir), None, true);
    assert!(!resumed.summary().crashed);
    let sum = resumed.summary();
    assert_eq!(sum.jobs.len(), 2, "both trace jobs reported after resume");
    for (f, r) in full.summary().jobs.iter().zip(&sum.jobs) {
        assert_eq!(f.name, r.name);
        assert_eq!(
            f.records.len(),
            r.records.len(),
            "job {}: resume must finish every round",
            r.name
        );
        assert_eq!(
            f.final_model, r.final_model,
            "job {}: re-admitted job must converge to the reference model",
            r.name
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 4. Real kill -9 across a real process boundary
// ---------------------------------------------------------------------------

/// Run the fljit binary with the given args, panicking on spawn failure.
#[cfg(unix)]
fn fljit(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_fljit"))
        .args(args)
        .output()
        .expect("spawn fljit")
}

/// The greppable `job=.. rounds=.. model_crc32=..` lines from
/// `fljit recover <dir>` — the durability smoke's comparison key.
#[cfg(unix)]
fn recover_crc_lines(dir: &std::path::Path) -> Vec<String> {
    let out = fljit(&["recover", &dir.to_string_lossy()]);
    assert!(
        out.status.success(),
        "recover failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout)
        .lines()
        .filter(|l| l.starts_with("job="))
        .map(|l| l.to_string())
        .collect()
}

#[cfg(unix)]
#[test]
fn sigkilled_subprocess_resumes_to_reference_model_crcs() {
    let base = [
        "live", "--strategy", "jit", "--parties", "4", "--rounds", "3", "--dim", "16",
        "--seed", "11", "--scripted",
    ];
    // reference: the identical job uninterrupted on its own durable dir
    let ref_dir = tmp("sig_ref");
    let ref_dir_s = ref_dir.to_string_lossy().to_string();
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--data-dir", &ref_dir_s]);
    let out = fljit(&args);
    assert!(
        out.status.success(),
        "reference run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let want = recover_crc_lines(&ref_dir);
    assert!(!want.is_empty(), "reference run published models");

    // victim: the same job paced on the wall clock, SIGKILLed mid-run
    let kill_dir = tmp("sig_kill");
    let kill_dir_s = kill_dir.to_string_lossy().to_string();
    let mut args: Vec<&str> = base.to_vec();
    args.extend([
        "--wall", "--epoch-secs", "0.5", "--fsync", "always", "--data-dir", &kill_dir_s,
    ]);
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_fljit"))
        .args(&args)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn victim");
    // let it get partway into its ~1.5s+ of wall-paced rounds, then a
    // real SIGKILL: no destructors, no flush, the page cache is all
    // that saves the tail
    std::thread::sleep(std::time::Duration::from_millis(900));
    child.kill().expect("kill -9");
    let _ = child.wait();

    // the torn log must recover cleanly (exit 0, possibly a truncated
    // tail) and a resume must finish the job to the reference CRCs
    let _ = recover_crc_lines(&kill_dir);
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--resume", "--data-dir", &kill_dir_s]);
    let out = fljit(&args);
    assert!(
        out.status.success(),
        "resume run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let got = recover_crc_lines(&kill_dir);
    assert_eq!(
        got, want,
        "killed-and-resumed run must converge to the reference model CRCs"
    );
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&kill_dir);
}

// ---------------------------------------------------------------------------
// Recovery edge cases at the MQ level (the WAL-level ones live in
// `wal::tests`): an empty dir and a CRC-corrupted mid-log record.
// ---------------------------------------------------------------------------

#[test]
fn durable_open_on_fresh_dir_is_an_empty_queue() {
    let dir = tmp("fresh");
    let q = MessageQueue::durable(WalConfig::new(&dir)).expect("open fresh");
    assert_eq!(q.produced(), 0);
    assert!(q.topic_names().is_empty());
    assert!(q.recovery().expect("report").records == 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_mid_log_record_fails_loudly_not_silently() {
    use std::io::{Seek, SeekFrom, Write};
    let dir = tmp("corrupt");
    {
        let s = Session::live().seed(11).dim(16).data_dir(&dir);
        let mut s = s;
        s.job(spec(3, 2), "jit");
        s.run().expect("seed run");
    }
    // flip bytes in the middle of the first segment's first record body
    let seg = dir.join("000000000000.wal");
    let mut f = std::fs::OpenOptions::new()
        .write(true)
        .open(&seg)
        .expect("open segment");
    f.seek(SeekFrom::Start(32)).unwrap();
    f.write_all(&[0xAA; 8]).unwrap();
    f.sync_all().unwrap();
    drop(f);
    let err = MessageQueue::durable(WalConfig::new(&dir));
    assert!(err.is_err(), "mid-log corruption must be a hard error");
    let msg = format!("{}", err.err().unwrap());
    assert!(
        msg.contains("corrupt"),
        "error must name the corruption, got: {msg}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
