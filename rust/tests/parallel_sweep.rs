//! The parallel grid sweep must be *bit-identical* to the sequential one:
//! every scenario cell owns its platform and seeded RNG, so fanning cells
//! out across the worker pool may only change wall-clock time, never a
//! single reported number.

use fljit::bench::figs::run_cells;
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::run_scenario;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

#[test]
fn parallel_sweep_is_bit_identical_to_sequential() {
    let strategies = ["jit", "batched", "eager-serverless", "eager-ao"];
    let fleets = [
        FleetKind::ActiveHomogeneous,
        FleetKind::ActiveHeterogeneous,
        FleetKind::IntermittentHeterogeneous,
    ];
    let mut cells = Vec::new();
    for (i, &fleet) in fleets.iter().enumerate() {
        for &strat in &strategies {
            let spec = FlJobSpec::new(
                Workload::cifar100_effnet(),
                fleet,
                6 + 2 * i, // vary the fleet size a little per row
                2,
            );
            cells.push((spec, strat, 0xBEE5 + i as u64));
        }
    }
    let sequential: Vec<_> = cells
        .iter()
        .map(|(spec, strat, seed)| run_scenario(spec, strat, *seed))
        .collect();
    let parallel = run_cells(cells);
    assert_eq!(parallel.len(), sequential.len());
    for (p, s) in parallel.iter().zip(&sequential) {
        assert_eq!(
            p.to_json(),
            s.to_json(),
            "parallel cell diverged from sequential ({}/{})",
            p.strategy,
            p.fleet
        );
    }
}

#[test]
fn run_cells_preserves_cell_order() {
    let cells: Vec<_> = ["eager-ao", "jit", "batched"]
        .iter()
        .map(|&s| {
            (
                FlJobSpec::new(Workload::inat_inception(), FleetKind::ActiveHomogeneous, 5, 1),
                s,
                3u64,
            )
        })
        .collect();
    let reports = run_cells(cells);
    let names: Vec<&str> = reports.iter().map(|r| r.strategy.as_str()).collect();
    assert_eq!(names, vec!["eager-ao", "jit", "batched"]);
}
