//! Golden-file pin for the on-disk `JobTrace` format (ROADMAP carried
//! item: record a production workload once, replay it under every
//! arbitration policy forever). If the format drifts, this test — not a
//! user's archived trace — is what breaks.

use std::path::PathBuf;

use fljit::broker::workload::{poisson_trace, JobTrace, TraceConfig};
use fljit::broker::SloClass;
use fljit::coordinator::session::Session;
use fljit::party::FleetKind;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/job_trace.golden.json")
}

#[test]
fn golden_trace_loads_with_every_field() {
    let t = JobTrace::load(&golden_path()).expect("golden trace must parse");
    assert_eq!(t.len(), 2);

    let a = &t.arrivals[0];
    assert_eq!(a.at_secs, 0.0);
    assert_eq!(a.class, SloClass::Premium);
    assert_eq!(a.strategy, "jit");
    assert_eq!(a.spec.name, "golden-cifar-10p");
    assert_eq!(a.spec.workload.name, "cifar100-effnet");
    assert_eq!(a.spec.fleet_kind, FleetKind::ActiveHomogeneous);
    assert_eq!(a.spec.n_parties, 10);
    assert_eq!(a.spec.rounds, 3);
    assert_eq!(a.spec.quorum, 8);
    assert_eq!(a.spec.report_prob, 0.9);

    let b = &t.arrivals[1];
    assert_eq!(b.at_secs, 42.5);
    assert_eq!(b.class, SloClass::BestEffort);
    assert_eq!(b.strategy, "eager-ao");
    assert_eq!(b.spec.fleet_kind, FleetKind::IntermittentHeterogeneous);
    assert_eq!(b.spec.t_wait_secs, 120.0);
    assert_eq!(t.max_parties(), 100);
}

#[test]
fn golden_trace_resaves_identically() {
    // save(load(golden)) must parse back to the same structure — the
    // format is stable in both directions
    let t = JobTrace::load(&golden_path()).expect("golden");
    let reparsed = JobTrace::from_json(&t.to_json()).expect("reparse");
    assert_eq!(t.len(), reparsed.len());
    for (x, y) in t.arrivals.iter().zip(&reparsed.arrivals) {
        assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits());
        assert_eq!(x.spec.name, y.spec.name);
        assert_eq!(x.spec.quorum, y.spec.quorum);
        assert_eq!(x.class, y.class);
        assert_eq!(x.strategy, y.strategy);
    }
}

#[test]
fn saved_trace_replays_identically_to_the_original() {
    // a generated trace, persisted and reloaded, must drive the broker to
    // bit-identical per-job outcomes
    let trace = poisson_trace(&TraceConfig {
        n_jobs: 3,
        mean_interarrival_secs: 10.0,
        party_mix: vec![(6, 1.0)],
        intermittent_frac: 0.0,
        rounds_lo: 2,
        rounds_hi: 2,
        t_wait_secs: 60.0,
        seed: 51,
        ..Default::default()
    });
    let dir = std::env::temp_dir().join("fljit_trace_replay");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("replay.json");
    trace.save(&path).unwrap();
    let reloaded = JobTrace::load(&path).unwrap();

    let replay = |t: &JobTrace| {
        Session::sim()
            .trace(t)
            .capacity(8)
            .seed(77)
            .run()
            .expect("trace replay")
    };
    let a = replay(&trace);
    let b = replay(&reloaded);
    let (a, b) = (a.summary(), b.summary());
    assert_eq!(a.jobs.len(), b.jobs.len());
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.queue_wait_secs.to_bits(), y.queue_wait_secs.to_bits());
        assert_eq!(
            x.container_seconds.to_bits(),
            y.container_seconds.to_bits()
        );
        assert_eq!(x.records.len(), y.records.len());
    }
    assert_eq!(a.span_secs.to_bits(), b.span_secs.to_bits());
}
