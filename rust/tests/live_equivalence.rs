//! Sim/live equivalence **through the `Session` façade**: a live session
//! (wall-clock driver with a mocked instant clock, scripted parties)
//! must produce the *same* fuse-count and round-record sequence as a sim
//! session for the same seed, spec and strategy.
//!
//! Both regimes run the identical `JobEngine` + `Strategy` code; the sim
//! pre-schedules arrival events from the fleet model while the live path
//! publishes the same drawn offsets into the zero-copy MQ and lets the
//! wall driver ingest them back as arrival events. If the two event
//! streams diverge anywhere — times, ordering, estimator feeding, round
//! completion — these comparisons break bit-for-bit.

use fljit::adapt::AdaptiveConfig;
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::Session;
use fljit::party::{FleetFaults, FleetKind};
use fljit::workloads::Workload;

fn assert_equivalent(strategy: &str, fleet: FleetKind, parties: usize, rounds: u32, seed: u64) {
    assert_equivalent_under(strategy, fleet, parties, rounds, seed, FleetFaults::none());
}

fn assert_equivalent_under(
    strategy: &str,
    fleet: FleetKind,
    parties: usize,
    rounds: u32,
    seed: u64,
    faults: FleetFaults,
) {
    assert_equivalent_cfg(
        strategy,
        fleet,
        parties,
        rounds,
        seed,
        faults,
        AdaptiveConfig::none(),
    );
}

#[allow(clippy::too_many_arguments)]
fn assert_equivalent_cfg(
    strategy: &str,
    fleet: FleetKind,
    parties: usize,
    rounds: u32,
    seed: u64,
    faults: FleetFaults,
    adaptive: AdaptiveConfig,
) {
    let workload = Workload::cifar100_effnet();
    let spec = FlJobSpec::new(workload, fleet, parties, rounds);

    let mut s = Session::sim()
        .seed(seed)
        .faults(faults)
        .adaptive(adaptive.clone());
    let hs = s.job(spec.clone(), strategy);
    let sim_rep = s.run().unwrap_or_else(|e| panic!("{strategy}/{fleet:?} sim run: {e:#}"));
    let sim = sim_rep.job(hs);

    let mut l = Session::live()
        .seed(seed)
        .dim(64)
        .faults(faults)
        .adaptive(adaptive);
    let hl = l.job(spec, strategy);
    let live_rep = l
        .run()
        .unwrap_or_else(|e| panic!("{strategy}/{fleet:?} live run: {e:#}"));
    let live = live_rep.job(hl);

    assert_eq!(
        sim.records.len(),
        live.records.len(),
        "{strategy}/{fleet:?}: round count"
    );
    for (a, b) in sim.records.iter().zip(&live.records) {
        assert_eq!(a.round, b.round, "{strategy}: round index");
        assert_eq!(
            a.latency_secs.to_bits(),
            b.latency_secs.to_bits(),
            "{strategy} round {}: latency {} vs {}",
            a.round,
            a.latency_secs,
            b.latency_secs
        );
        assert_eq!(
            a.last_arrival_secs.to_bits(),
            b.last_arrival_secs.to_bits(),
            "{strategy} round {}: last arrival {} vs {}",
            a.round,
            a.last_arrival_secs,
            b.last_arrival_secs
        );
        assert_eq!(
            a.complete_secs.to_bits(),
            b.complete_secs.to_bits(),
            "{strategy} round {}: complete {} vs {}",
            a.round,
            a.complete_secs,
            b.complete_secs
        );
    }
    assert_eq!(
        sim.updates_fused, live.updates_fused,
        "{strategy}/{fleet:?}: emulated fuse count"
    );
    assert_eq!(
        sim.updates_fused, live.updates_folded,
        "{strategy}/{fleet:?}: the live path folds every emulated merge for real"
    );
    assert_eq!(
        sim.deployments, live.deployments,
        "{strategy}/{fleet:?}: deployments"
    );
    assert_eq!(
        (sim.updates_dropped, sim.updates_decayed, sim.rounds_skipped),
        (live.updates_dropped, live.updates_decayed, live.rounds_skipped),
        "{strategy}/{fleet:?}: degradation counters"
    );
}

/// Dropout churn + heavy-tailed stragglers with a reporting deadline —
/// the hostile cell the drop-policy equivalence pins run under.
fn hostile_faults() -> FleetFaults {
    FleetFaults {
        dropout_prob: 0.2,
        rejoin_after: 1,
        straggler_prob: 0.3,
        straggler_alpha: 1.2,
        upload_tail_sigma: 0.3,
        straggler_cutoff_secs: Some(Workload::cifar100_effnet().base_epoch_secs * 2.0),
        ..FleetFaults::default()
    }
}

#[test]
fn jit_active_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHomogeneous, 10, 3, 0xE1);
}

#[test]
fn jit_heterogeneous_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHeterogeneous, 8, 3, 0xE2);
}

#[test]
fn batched_matches_sim() {
    assert_equivalent("batched", FleetKind::ActiveHomogeneous, 10, 2, 0xE3);
}

#[test]
fn eager_serverless_matches_sim() {
    assert_equivalent("eager-serverless", FleetKind::ActiveHomogeneous, 8, 2, 0xE4);
}

#[test]
fn eager_ao_matches_sim() {
    assert_equivalent("eager-ao", FleetKind::ActiveHomogeneous, 8, 2, 0xE5);
}

#[test]
fn lazy_matches_sim() {
    assert_equivalent("lazy", FleetKind::ActiveHomogeneous, 8, 2, 0xE6);
}

#[test]
fn jit_intermittent_matches_sim() {
    // intermittent fleets pace rounds by t_wait; both sides use the
    // workload-default window so the specs are identical
    assert_equivalent("jit", FleetKind::IntermittentHeterogeneous, 6, 2, 0xE7);
}

/// The façade must add no behavior of its own on the sim side: a
/// single-job `Session::sim()` reproduces `run_scenario` bit-for-bit
/// (the deadline arbitration policy it installs is pinned ≡ the
/// no-policy scheduler elsewhere).
#[test]
fn sim_session_matches_run_scenario_bit_for_bit() {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHeterogeneous,
        10,
        3,
    );
    let legacy = fljit::coordinator::platform::run_scenario(&spec, "jit", 0xE8);
    let mut s = Session::sim().seed(0xE8);
    let h = s.job(spec, "jit");
    let rep = s.run().expect("sim session");
    let o = rep.job(h);
    assert_eq!(legacy.rounds.len(), o.records.len());
    for (a, b) in legacy.rounds.iter().zip(&o.records) {
        assert_eq!(a.latency_secs.to_bits(), b.latency_secs.to_bits());
        assert_eq!(a.complete_secs.to_bits(), b.complete_secs.to_bits());
    }
    assert_eq!(legacy.updates_fused, o.updates_fused);
    assert_eq!(legacy.deployments, o.deployments);
    assert_eq!(legacy.makespan_secs.to_bits(), o.makespan_secs.to_bits());
    assert_eq!(
        legacy.container_seconds.to_bits(),
        o.container_seconds.to_bits()
    );
}

/// `async-stale` on a healthy fleet is jit with a different stale
/// policy that never triggers — sim/live equivalence holds bit-for-bit.
#[test]
fn async_stale_healthy_matches_sim() {
    assert_equivalent("async-stale", FleetKind::ActiveHomogeneous, 8, 2, 0xE9);
}

/// The drop-policy strategies cut deadline-missers at the source, so the
/// faulty sim and live event streams stay identical: one hostile cell
/// (dropout + stragglers) per strategy, pinned bit-for-bit.
#[test]
fn drop_strategies_match_sim_bit_for_bit_under_a_hostile_fleet() {
    for (i, strategy) in ["jit", "batched", "eager-serverless", "eager-ao", "lazy"]
        .iter()
        .enumerate()
    {
        assert_equivalent_under(
            strategy,
            FleetKind::ActiveHomogeneous,
            10,
            3,
            0xF0 + i as u64,
            hostile_faults(),
        );
    }
}

/// `async-stale` under faults self-schedules its late deliveries on the
/// live driver (an epsilon after the drawn offset), so sim and live are
/// not compared bit-for-bit there; instead the live run itself must be
/// bit-reproducible per seed, and must actually decay late updates
/// rather than dropping them.
#[test]
fn async_stale_faulty_live_runs_are_deterministic_and_decay() {
    let workload = Workload::cifar100_effnet();
    let faults = FleetFaults::scenario("stragglers", workload.base_epoch_secs).unwrap();
    let run = || {
        let mut s = Session::live().seed(0xEA).dim(64).faults(faults);
        let h = s.job(
            FlJobSpec::new(workload.clone(), FleetKind::ActiveHomogeneous, 12, 3),
            "async-stale",
        );
        let rep = s.run().expect("async-stale faulty live run");
        (rep, h)
    };
    let (a, ha) = run();
    let (b, hb) = run();
    let (a, b) = (a.job(ha), b.job(hb));
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.latency_secs.to_bits(), y.latency_secs.to_bits());
        assert_eq!(x.complete_secs.to_bits(), y.complete_secs.to_bits());
    }
    assert_eq!(a.final_model, b.final_model, "bit-identical final model");
    assert_eq!(a.updates_decayed, b.updates_decayed);
    assert_eq!(a.updates_dropped, b.updates_dropped);
    assert!(
        a.updates_decayed > 0,
        "the straggler scenario must produce decayed folds, got 0 \
         (dropped {}, rounds {})",
        a.updates_dropped,
        a.records.len()
    );
}

/// §5.5 under a hostile fleet: kill the live aggregator mid-run with
/// fault injection on, resume from the MQ, and the model stream must be
/// bit-identical to the uninterrupted faulty run — the resume replay
/// fast-forwards the *fault* rng stream too.
#[test]
fn kill_resume_under_faults_resumes_bit_identical() {
    use fljit::mq::{self, MessageQueue};
    use std::sync::Arc;

    let faults = hostile_faults();
    let session = |mq: &Arc<MessageQueue>, kill: Option<u64>, resume: bool| {
        let mut s = Session::live()
            .seed(0xEC)
            .dim(32)
            .on(mq)
            .kill_after_fuses(kill)
            .resume(resume)
            .faults(faults);
        let h = s.job(
            FlJobSpec::new(
                Workload::cifar100_effnet(),
                FleetKind::ActiveHomogeneous,
                6,
                3,
            ),
            "jit",
        );
        (s.run().expect("session run"), h)
    };

    let mq_full = Arc::new(MessageQueue::new());
    let (full, hf) = session(&mq_full, None, false);
    assert!(!full.summary().crashed);
    let published = mq_full.end_offset(&mq::model_topic(0));
    assert!(published > 0, "the faulty run must publish models");

    let mq_kill = Arc::new(MessageQueue::new());
    let (dead, _) = session(&mq_kill, Some(3), false);
    assert!(dead.summary().crashed, "fault injection must trip");

    let (resumed, hr) = session(&mq_kill, None, true);
    assert!(!resumed.summary().crashed);
    assert_eq!(
        mq_kill.end_offset(&mq::model_topic(0)),
        published,
        "resume must publish the remaining rounds"
    );
    for round in 0..published {
        let a = mq_full.fetch(&mq::model_topic(0), round, 1);
        let b = mq_kill.fetch(&mq::model_topic(0), round, 1);
        assert_eq!(
            a[0].payload.data().unwrap(),
            b[0].payload.data().unwrap(),
            "round {round} model must be bit-identical under faults"
        );
    }
    assert_eq!(resumed.job(hr).final_model, full.job(hf).final_model);
}

/// PR 10 determinism pin: with the adaptive policy *enabled*, the learned
/// deadlines / cutoffs are pure functions of the arrival stream — no rng
/// of their own — so sim and live still agree bit-for-bit, including
/// under the hostile fleet where the sketch actually moves the deadline
/// and restores degraded quorums.
#[test]
fn adaptive_jit_matches_sim_bit_for_bit_under_a_hostile_fleet() {
    assert_equivalent_cfg(
        "jit",
        FleetKind::ActiveHomogeneous,
        10,
        3,
        0xAD1,
        hostile_faults(),
        AdaptiveConfig::on(),
    );
    // and on a healthy fleet, where the policy observes but the timer
    // never wins (rounds fuse on full arrival)
    assert_equivalent_cfg(
        "jit",
        FleetKind::ActiveHeterogeneous,
        8,
        3,
        0xAD2,
        FleetFaults::none(),
        AdaptiveConfig::on(),
    );
}

/// §5.5 × PR 10: kill the live aggregator mid-run with the adaptive
/// policy on, resume from the MQ, and the model stream must be
/// bit-identical to the uninterrupted adaptive run. The learned sketch
/// checkpoints through its own MQ slot at each round completion; resume
/// reloads it and the open round's replayed arrivals re-observe, so the
/// resumed policy re-arms the *same* deadlines as the uninterrupted one.
#[test]
fn kill_resume_under_adaptive_resumes_bit_identical() {
    use fljit::mq::{self, MessageQueue};
    use std::sync::Arc;

    let faults = hostile_faults();
    let session = |mq: &Arc<MessageQueue>, kill: Option<u64>, resume: bool| {
        let mut s = Session::live()
            .seed(0xAD3)
            .dim(32)
            .on(mq)
            .kill_after_fuses(kill)
            .resume(resume)
            .faults(faults)
            .adaptive(AdaptiveConfig::on());
        let h = s.job(
            FlJobSpec::new(
                Workload::cifar100_effnet(),
                FleetKind::ActiveHomogeneous,
                6,
                3,
            ),
            "jit",
        );
        (s.run().expect("session run"), h)
    };

    let mq_full = Arc::new(MessageQueue::new());
    let (full, hf) = session(&mq_full, None, false);
    assert!(!full.summary().crashed);
    let published = mq_full.end_offset(&mq::model_topic(0));
    assert!(published > 0, "the adaptive run must publish models");

    let mq_kill = Arc::new(MessageQueue::new());
    let (dead, _) = session(&mq_kill, Some(3), false);
    assert!(dead.summary().crashed, "fault injection must trip");

    let (resumed, hr) = session(&mq_kill, None, true);
    assert!(!resumed.summary().crashed);
    assert_eq!(
        mq_kill.end_offset(&mq::model_topic(0)),
        published,
        "resume must publish the remaining rounds"
    );
    for round in 0..published {
        let a = mq_full.fetch(&mq::model_topic(0), round, 1);
        let b = mq_kill.fetch(&mq::model_topic(0), round, 1);
        assert_eq!(
            a[0].payload.data().unwrap(),
            b[0].payload.data().unwrap(),
            "round {round} model must be bit-identical with the adaptive \
             policy resumed from its sketch checkpoint"
        );
    }
    assert_eq!(resumed.job(hr).final_model, full.job(hf).final_model);
}
