//! Sim/live equivalence: the wall-clock driver with a mocked instant
//! clock must produce the *same* fuse-count and round-record sequence as
//! the simulator for the same seed, spec and strategy.
//!
//! Both regimes run the identical `JobEngine` + `Strategy` code; the sim
//! pre-schedules arrival events from the fleet model while the live path
//! publishes the same drawn offsets into the zero-copy MQ and lets the
//! wall driver ingest them back as arrival events. If the two event
//! streams diverge anywhere — times, ordering, estimator feeding, round
//! completion — these comparisons break bit-for-bit.

use std::sync::Arc;

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::live::{run_live_on, LiveConfig, PartyBackend};
use fljit::coordinator::platform::run_scenario;
use fljit::mq::MessageQueue;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

fn assert_equivalent(strategy: &str, fleet: FleetKind, parties: usize, rounds: u32, seed: u64) {
    let workload = Workload::cifar100_effnet();
    let spec = FlJobSpec::new(workload.clone(), fleet, parties, rounds);
    let sim = run_scenario(&spec, strategy, seed);

    let cfg = LiveConfig {
        strategy: strategy.to_string(),
        n_parties: parties,
        rounds,
        seed,
        workload,
        fleet,
        backend: PartyBackend::Scripted,
        dim: 64,
        ..Default::default()
    };
    let live = run_live_on(&cfg, &Arc::new(MessageQueue::new()), false)
        .unwrap_or_else(|e| panic!("{strategy}/{fleet:?} live run: {e:#}"));

    assert_eq!(
        sim.rounds.len(),
        live.records.len(),
        "{strategy}/{fleet:?}: round count"
    );
    for (a, b) in sim.rounds.iter().zip(&live.records) {
        assert_eq!(a.round, b.round, "{strategy}: round index");
        assert_eq!(
            a.latency_secs.to_bits(),
            b.latency_secs.to_bits(),
            "{strategy} round {}: latency {} vs {}",
            a.round,
            a.latency_secs,
            b.latency_secs
        );
        assert_eq!(
            a.last_arrival_secs.to_bits(),
            b.last_arrival_secs.to_bits(),
            "{strategy} round {}: last arrival {} vs {}",
            a.round,
            a.last_arrival_secs,
            b.last_arrival_secs
        );
        assert_eq!(
            a.complete_secs.to_bits(),
            b.complete_secs.to_bits(),
            "{strategy} round {}: complete {} vs {}",
            a.round,
            a.complete_secs,
            b.complete_secs
        );
    }
    assert_eq!(
        sim.updates_fused, live.updates_fused,
        "{strategy}/{fleet:?}: fuse count"
    );
    assert_eq!(
        sim.deployments, live.deployments,
        "{strategy}/{fleet:?}: deployments"
    );
}

#[test]
fn jit_active_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHomogeneous, 10, 3, 0xE1);
}

#[test]
fn jit_heterogeneous_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHeterogeneous, 8, 3, 0xE2);
}

#[test]
fn batched_matches_sim() {
    assert_equivalent("batched", FleetKind::ActiveHomogeneous, 10, 2, 0xE3);
}

#[test]
fn eager_serverless_matches_sim() {
    assert_equivalent("eager-serverless", FleetKind::ActiveHomogeneous, 8, 2, 0xE4);
}

#[test]
fn eager_ao_matches_sim() {
    assert_equivalent("eager-ao", FleetKind::ActiveHomogeneous, 8, 2, 0xE5);
}

#[test]
fn lazy_matches_sim() {
    assert_equivalent("lazy", FleetKind::ActiveHomogeneous, 8, 2, 0xE6);
}

#[test]
fn jit_intermittent_matches_sim() {
    // intermittent fleets pace rounds by t_wait; both sides use the
    // workload-default window so the specs are identical
    assert_equivalent("jit", FleetKind::IntermittentHeterogeneous, 6, 2, 0xE7);
}
