//! Sim/live equivalence **through the `Session` façade**: a live session
//! (wall-clock driver with a mocked instant clock, scripted parties)
//! must produce the *same* fuse-count and round-record sequence as a sim
//! session for the same seed, spec and strategy.
//!
//! Both regimes run the identical `JobEngine` + `Strategy` code; the sim
//! pre-schedules arrival events from the fleet model while the live path
//! publishes the same drawn offsets into the zero-copy MQ and lets the
//! wall driver ingest them back as arrival events. If the two event
//! streams diverge anywhere — times, ordering, estimator feeding, round
//! completion — these comparisons break bit-for-bit.

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::Session;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

fn assert_equivalent(strategy: &str, fleet: FleetKind, parties: usize, rounds: u32, seed: u64) {
    let workload = Workload::cifar100_effnet();
    let spec = FlJobSpec::new(workload, fleet, parties, rounds);

    let mut s = Session::sim().seed(seed);
    let hs = s.job(spec.clone(), strategy);
    let sim_rep = s.run().unwrap_or_else(|e| panic!("{strategy}/{fleet:?} sim run: {e:#}"));
    let sim = sim_rep.job(hs);

    let mut l = Session::live().seed(seed).dim(64);
    let hl = l.job(spec, strategy);
    let live_rep = l
        .run()
        .unwrap_or_else(|e| panic!("{strategy}/{fleet:?} live run: {e:#}"));
    let live = live_rep.job(hl);

    assert_eq!(
        sim.records.len(),
        live.records.len(),
        "{strategy}/{fleet:?}: round count"
    );
    for (a, b) in sim.records.iter().zip(&live.records) {
        assert_eq!(a.round, b.round, "{strategy}: round index");
        assert_eq!(
            a.latency_secs.to_bits(),
            b.latency_secs.to_bits(),
            "{strategy} round {}: latency {} vs {}",
            a.round,
            a.latency_secs,
            b.latency_secs
        );
        assert_eq!(
            a.last_arrival_secs.to_bits(),
            b.last_arrival_secs.to_bits(),
            "{strategy} round {}: last arrival {} vs {}",
            a.round,
            a.last_arrival_secs,
            b.last_arrival_secs
        );
        assert_eq!(
            a.complete_secs.to_bits(),
            b.complete_secs.to_bits(),
            "{strategy} round {}: complete {} vs {}",
            a.round,
            a.complete_secs,
            b.complete_secs
        );
    }
    assert_eq!(
        sim.updates_fused, live.updates_fused,
        "{strategy}/{fleet:?}: emulated fuse count"
    );
    assert_eq!(
        sim.updates_fused, live.updates_folded,
        "{strategy}/{fleet:?}: the live path folds every emulated merge for real"
    );
    assert_eq!(
        sim.deployments, live.deployments,
        "{strategy}/{fleet:?}: deployments"
    );
}

#[test]
fn jit_active_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHomogeneous, 10, 3, 0xE1);
}

#[test]
fn jit_heterogeneous_matches_sim() {
    assert_equivalent("jit", FleetKind::ActiveHeterogeneous, 8, 3, 0xE2);
}

#[test]
fn batched_matches_sim() {
    assert_equivalent("batched", FleetKind::ActiveHomogeneous, 10, 2, 0xE3);
}

#[test]
fn eager_serverless_matches_sim() {
    assert_equivalent("eager-serverless", FleetKind::ActiveHomogeneous, 8, 2, 0xE4);
}

#[test]
fn eager_ao_matches_sim() {
    assert_equivalent("eager-ao", FleetKind::ActiveHomogeneous, 8, 2, 0xE5);
}

#[test]
fn lazy_matches_sim() {
    assert_equivalent("lazy", FleetKind::ActiveHomogeneous, 8, 2, 0xE6);
}

#[test]
fn jit_intermittent_matches_sim() {
    // intermittent fleets pace rounds by t_wait; both sides use the
    // workload-default window so the specs are identical
    assert_equivalent("jit", FleetKind::IntermittentHeterogeneous, 6, 2, 0xE7);
}

/// The façade must add no behavior of its own on the sim side: a
/// single-job `Session::sim()` reproduces `run_scenario` bit-for-bit
/// (the deadline arbitration policy it installs is pinned ≡ the
/// no-policy scheduler elsewhere).
#[test]
fn sim_session_matches_run_scenario_bit_for_bit() {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHeterogeneous,
        10,
        3,
    );
    let legacy = fljit::coordinator::platform::run_scenario(&spec, "jit", 0xE8);
    let mut s = Session::sim().seed(0xE8);
    let h = s.job(spec, "jit");
    let rep = s.run().expect("sim session");
    let o = rep.job(h);
    assert_eq!(legacy.rounds.len(), o.records.len());
    for (a, b) in legacy.rounds.iter().zip(&o.records) {
        assert_eq!(a.latency_secs.to_bits(), b.latency_secs.to_bits());
        assert_eq!(a.complete_secs.to_bits(), b.complete_secs.to_bits());
    }
    assert_eq!(legacy.updates_fused, o.updates_fused);
    assert_eq!(legacy.deployments, o.deployments);
    assert_eq!(legacy.makespan_secs.to_bits(), o.makespan_secs.to_bits());
    assert_eq!(
        legacy.container_seconds.to_bits(),
        o.container_seconds.to_bits()
    );
}
