//! Integration: whole-platform scenarios over the discrete-event engine —
//! the Fig 2 illustration, paper-grid cells, multi-tenant preemption and
//! the estimator's fallback paths, all through the public API.

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::{run_scenario, Platform, PlatformConfig};
use fljit::coordinator::timeline;
use fljit::metrics::savings_pct;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

#[test]
fn fig2_scenario_reproduces_section3_story() {
    let reports = timeline::run_fig2(7);
    let get = |n: &str| reports.iter().find(|r| r.strategy == n).unwrap();
    let (jit, lazy, eager, ao) = (
        get("jit"),
        get("lazy"),
        get("eager-serverless"),
        get("eager-ao"),
    );
    // §3: eager AO has minimal latency but idles most of the round
    assert!(ao.mean_latency_secs() <= jit.mean_latency_secs() + 0.5);
    assert!(ao.total_container_seconds() > 3.0 * jit.total_container_seconds());
    // lazy is cheapest but pays the whole aggregation after t_rnd
    assert!(lazy.total_container_seconds() <= jit.total_container_seconds() + 1.0);
    assert!(lazy.mean_latency_secs() > 2.0 * eager.mean_latency_secs());
}

#[test]
fn paper_bands_hold_on_a_mid_cell() {
    // 100-party active heterogeneous CIFAR100 (a middle Fig 9 cell)
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHeterogeneous,
        100,
        10,
    );
    let jit = run_scenario(&spec, "jit", 3);
    let batch = run_scenario(&spec, "batched", 3);
    let eager = run_scenario(&spec, "eager-serverless", 3);
    let ao = run_scenario(&spec, "eager-ao", 3);
    // Fig 9 bands (±: we accept anywhere clearly inside the shape)
    let s_batch = savings_pct(&jit, &batch);
    let s_eager = savings_pct(&jit, &eager);
    let s_ao = savings_pct(&jit, &ao);
    assert!(s_batch > 15.0, "JIT vs batch savings {s_batch}%");
    assert!(s_eager > 55.0, "JIT vs eager savings {s_eager}%");
    assert!(s_ao > 85.0, "JIT vs AO savings {s_ao}%");
    // Fig 8: JIT latency comparable to eager (within 2s), batch worse
    assert!(jit.mean_latency_secs() < eager.mean_latency_secs() + 2.0);
    assert!(batch.mean_latency_secs() >= jit.mean_latency_secs());
    // everything fused everywhere
    for r in [&jit, &batch, &eager, &ao] {
        assert_eq!(r.updates_fused, 100 * 10, "{}", r.strategy);
    }
}

#[test]
fn intermittent_fig7_cell_savings_exceed_99pct_vs_ao() {
    let mut spec = FlJobSpec::new(
        Workload::inat_inception(),
        FleetKind::IntermittentHeterogeneous,
        100,
        5,
    );
    spec.t_wait_secs = 300.0;
    let jit = run_scenario(&spec, "jit", 11);
    let ao = run_scenario(&spec, "eager-ao", 11);
    assert!(savings_pct(&jit, &ao) > 99.0);
    // latency must stay low even though updates land anywhere in the window
    assert!(jit.mean_latency_secs() < 5.0, "{}", jit.mean_latency_secs());
}

#[test]
fn multi_tenant_jobs_contend_and_all_finish() {
    // several jobs of mixed priority share a small cluster — exercises the
    // δ-tick priority scheduler and preemption across jobs (§5.5)
    let mut cfg = PlatformConfig::default();
    cfg.cluster.capacity = 3;
    let mut p = Platform::new(cfg);
    for i in 0..4 {
        let mut spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            if i % 2 == 0 {
                FleetKind::ActiveHomogeneous
            } else {
                FleetKind::ActiveHeterogeneous
            },
            6,
            3,
        );
        spec.name = format!("tenant-{i}");
        p.admit(spec, "jit");
    }
    let reports = p.run();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert_eq!(r.rounds.len(), 3, "{} finished all rounds", r.strategy);
        assert_eq!(r.updates_fused, 18);
        assert!(r.mean_latency_secs() < 20.0);
    }
}

#[test]
fn regression_fallback_still_predicts() {
    // parties refuse to report timings (report_prob = 0): the estimator
    // falls back to the cross-party linearity regression (§5.3); after a
    // couple of observed rounds JIT latency should still be eager-like
    let mut spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHeterogeneous,
        20,
        8,
    );
    spec.report_prob = 0.0;
    let jit = run_scenario(&spec, "jit", 21);
    assert_eq!(jit.rounds.len(), 8);
    // later rounds (history available) must have low latency
    let tail: Vec<f64> = jit.rounds[3..].iter().map(|r| r.latency_secs).collect();
    let tail_mean = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!(tail_mean < 5.0, "tail latency {tail_mean}");
}

#[test]
fn quorum_rounds_complete_without_stragglers() {
    let mut spec = FlJobSpec::new(
        Workload::inat_inception(),
        FleetKind::ActiveHeterogeneous,
        12,
        4,
    );
    spec.quorum = 9; // tolerate 3 stragglers
    let r = run_scenario(&spec, "jit", 33);
    assert_eq!(r.rounds.len(), 4);
    // at least quorum × rounds fused (stragglers may or may not land)
    assert!(r.updates_fused >= 9 * 4, "fused {}", r.updates_fused);
}

#[test]
fn broker_multi_job_determinism_per_policy() {
    // PR 2 invariant: same seed + same arrival trace ⇒ bit-identical
    // JobReports, for every arbitration policy. The policies are pure
    // functions of the (deterministically ordered) candidate snapshot, so
    // two replays may not diverge in a single reported number.
    use fljit::broker::admission::AdmissionConfig;
    use fljit::broker::workload::{poisson_trace, TraceConfig};
    use fljit::coordinator::session::Session;

    let trace = poisson_trace(&TraceConfig {
        n_jobs: 5,
        mean_interarrival_secs: 8.0,
        party_mix: vec![(8, 0.5), (20, 0.5)],
        intermittent_frac: 0.25,
        rounds_lo: 2,
        rounds_hi: 3,
        t_wait_secs: 60.0,
        seed: 99,
        ..Default::default()
    });
    for policy in ["deadline", "least-slack", "wfs"] {
        let replay = || {
            Session::sim()
                .trace(&trace)
                .policy(policy)
                .admission(AdmissionConfig {
                    budget: 16,
                    max_jobs: 0,
                    autoscale: None,
                })
                .capacity(4) // scarce: arbitration decisions actually happen
                .seed(4242)
                .run()
                .unwrap_or_else(|e| panic!("policy '{policy}': {e:#}"))
        };
        let a = replay();
        let b = replay();
        let (a, b) = (a.summary(), b.summary());
        // every reported number must replay bit-identically (wall_secs is
        // the one genuinely non-deterministic field — real elapsed time)
        assert_eq!(a.preemptions, b.preemptions, "policy '{policy}'");
        assert_eq!(
            a.total_container_seconds.to_bits(),
            b.total_container_seconds.to_bits(),
            "policy '{policy}'"
        );
        assert_eq!(a.span_secs.to_bits(), b.span_secs.to_bits(), "policy '{policy}'");
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.queue_wait_secs.to_bits(), y.queue_wait_secs.to_bits());
            assert_eq!(x.container_seconds.to_bits(), y.container_seconds.to_bits());
            assert_eq!(x.records.len(), y.records.len());
            for (r, s) in x.records.iter().zip(&y.records) {
                assert_eq!(r.latency_secs.to_bits(), s.latency_secs.to_bits());
                assert_eq!(r.complete_secs.to_bits(), s.complete_secs.to_bits());
            }
        }
        for o in &a.jobs {
            assert_eq!(
                o.records.len() as u32,
                trace.arrivals[o.job].spec.rounds,
                "policy '{policy}' left job {} unfinished",
                o.name
            );
        }
    }
}

#[test]
fn deterministic_given_seed() {
    let spec = FlJobSpec::new(
        Workload::rvlcdip_vgg16(),
        FleetKind::IntermittentHeterogeneous,
        50,
        5,
    );
    let a = run_scenario(&spec, "jit", 1234);
    let b = run_scenario(&spec, "jit", 1234);
    assert_eq!(a.total_container_seconds(), b.total_container_seconds());
    assert_eq!(a.mean_latency_secs(), b.mean_latency_secs());
    assert_eq!(a.deployments, b.deployments);
    // Different seeds move the random arrival draws; container-seconds can
    // legitimately coincide (work is seed-independent) but latency — which
    // keys off the last arrival — should move.
    let c = run_scenario(&spec, "jit", 4321);
    assert_ne!(
        (a.mean_latency_secs() * 1e9) as u64,
        (c.mean_latency_secs() * 1e9) as u64,
        "different seeds should move latencies"
    );
}
