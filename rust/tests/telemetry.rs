//! Telemetry pins, through the `Session` façade.
//!
//! The subsystem's core contract is **passivity**: a run with telemetry
//! attached (metrics + streamed spans on disk) must produce a `Report`
//! bit-identical to the same run without it, in every regime — the
//! registry observes the seeded streams and never feeds back into them.
//! On top of that: the exporter files must actually appear and parse for
//! a multi-job live-broker sweep, the `SessionEvent` stream must stay a
//! deterministic function of the seed under fault injection (including
//! `RoundSkipped` sequences), and a consumer hanging up on the events
//! channel must never wedge or panic a live run.

use fljit::bench::live_broker::{run_sweep, LiveBrokerSweepConfig};
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::session::{Report, Session, SessionEvent};
use fljit::party::{FleetFaults, FleetKind};
use fljit::telemetry::{export, Registry};
use fljit::util::json::Json;
use fljit::workloads::Workload;

/// `Report::to_json` with the one nondeterministic field (real elapsed
/// time) scrubbed, so two runs of the same seeded session compare equal
/// byte for byte.
fn canonical(rep: &Report) -> String {
    let mut json = rep.to_json();
    if let Json::Obj(map) = &mut json {
        map.insert("wall_secs".to_string(), Json::Null);
    }
    json.pretty()
}

fn run_canonical(
    live: bool,
    strategy: &str,
    faults: FleetFaults,
    reg: Option<&Registry>,
) -> String {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHomogeneous,
        10,
        3,
    );
    let mut s = if live {
        Session::live().dim(32)
    } else {
        Session::sim()
    };
    s = s.seed(0x7E1E).faults(faults);
    if let Some(reg) = reg {
        s = s.telemetry(reg);
    }
    let _ = s.job(spec, strategy);
    let rep = s
        .run()
        .unwrap_or_else(|e| panic!("{strategy} live={live}: {e:#}"));
    canonical(&rep)
}

/// The tentpole pin: telemetry fully on (registry + streaming JSONL on
/// disk) changes nothing observable in the `Report`, for the default
/// drop policy and the decay policy, in both sim and live.
#[test]
fn telemetry_is_passive_reports_stay_bit_identical() {
    let base = Workload::cifar100_effnet().base_epoch_secs;
    let faults = FleetFaults::scenario("stragglers", base).unwrap();
    for strategy in ["jit", "async-stale"] {
        for live in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "fljit_tel_passive_{strategy}_{}",
                if live { "live" } else { "sim" }
            ));
            let reg = Registry::with_dir(&dir).expect("telemetry dir");
            let with = run_canonical(live, strategy, faults, Some(&reg));
            let without = run_canonical(live, strategy, faults, None);
            assert_eq!(
                with, without,
                "{strategy} live={live}: telemetry must not perturb the run"
            );
            let jsonl =
                std::fs::read_to_string(dir.join(export::JSONL_FILE)).expect("streamed JSONL");
            assert!(
                !jsonl.trim().is_empty(),
                "{strategy} live={live}: spans must stream during the run"
            );
        }
    }
}

/// Acceptance: a multi-job live-broker sweep with `--telemetry-dir`
/// produces all three artifacts, every JSONL line parses, the
/// exposition is well formed, and the Chrome trace carries events —
/// plus the `fljit top` summarizer finds per-job rows in the stream.
#[test]
fn live_broker_sweep_writes_all_three_exports() {
    let dir = std::env::temp_dir().join("fljit_tel_broker");
    let cfg = LiveBrokerSweepConfig {
        jobs: 3,
        max_parties: 4,
        capacity: 2,
        budget: 4,
        mean_interarrival_secs: 2.0,
        seed: 29,
        dim: 16,
        policy: "deadline".to_string(),
        telemetry_dir: Some(dir.to_string_lossy().to_string()),
        ..Default::default()
    };
    run_sweep(&cfg).expect("sweep with telemetry");

    let jsonl = std::fs::read_to_string(dir.join(export::JSONL_FILE)).expect("JSONL written");
    assert!(!jsonl.trim().is_empty());
    let mut spans = 0usize;
    let mut metrics = 0usize;
    for line in jsonl.lines().filter(|l| !l.trim().is_empty()) {
        let j = Json::parse(line).expect("every JSONL line is valid JSON");
        match j.get("kind").as_str() {
            Some("span") => spans += 1,
            Some("counter") | Some("gauge") | Some("histogram") => metrics += 1,
            other => panic!("unexpected kind {other:?} in line: {line}"),
        }
    }
    assert!(spans > 0, "round/fuse spans must be streamed");
    assert!(metrics > 0, "final metric samples must be appended");

    let prom =
        std::fs::read_to_string(dir.join(export::EXPOSITION_FILE)).expect("exposition written");
    assert!(prom.contains("# TYPE"), "typed exposition metadata");
    assert!(
        prom.contains("rounds_fused_total"),
        "engine counters reach the exposition"
    );
    assert!(
        prom.contains("mq_messages_produced_total"),
        "MQ counters reach the exposition"
    );

    let trace =
        std::fs::read_to_string(dir.join(export::CHROME_TRACE_FILE)).expect("trace written");
    let trace = Json::parse(&trace).expect("Chrome trace parses");
    let events = trace.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());

    let tops = export::summarize_jsonl(&jsonl);
    assert_eq!(tops.len(), 3, "`fljit top` sees every job in the stream");
    assert!(tops.iter().all(|t| t.rounds > 0));
}

fn collect_events(
    live: bool,
    strategy: &str,
    faults: FleetFaults,
    seed: u64,
    parties: usize,
    rounds: u32,
) -> Vec<SessionEvent> {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHomogeneous,
        parties,
        rounds,
    );
    let mut s = if live {
        Session::live().dim(16)
    } else {
        Session::sim()
    };
    s = s.seed(seed).faults(faults);
    let _ = s.job(spec, strategy);
    let rx = s.events();
    s.run()
        .unwrap_or_else(|e| panic!("{strategy} live={live}: {e:#}"));
    rx.try_iter().collect()
}

/// Satellite pin: under fault injection the event stream is a
/// deterministic function of the seed, in both regimes, for both the
/// straggler and the dropout scenario.
#[test]
fn fault_event_streams_are_deterministic_per_seed() {
    let base = Workload::cifar100_effnet().base_epoch_secs;
    for scenario in ["stragglers", "dropout"] {
        let faults = FleetFaults::scenario(scenario, base).unwrap();
        for live in [false, true] {
            let a = collect_events(live, "jit", faults, 0xA11CE, 10, 3);
            let b = collect_events(live, "jit", faults, 0xA11CE, 10, 3);
            assert!(!a.is_empty(), "{scenario} live={live}: events flow");
            assert_eq!(a, b, "{scenario} live={live}: same seed, same stream");
            assert!(
                a.iter()
                    .any(|e| matches!(e, SessionEvent::RoundFused { .. })),
                "{scenario} live={live}: rounds still complete"
            );
            // round numbering stays coherent even when rounds are
            // skipped: started/skipped indices are strictly increasing
            let mut last: Option<u32> = None;
            for ev in &a {
                let r = match ev {
                    SessionEvent::RoundStarted { round, .. }
                    | SessionEvent::RoundSkipped { round, .. } => *round,
                    _ => continue,
                };
                if let Some(prev) = last {
                    assert!(r > prev, "{scenario} live={live}: round {r} after {prev}");
                }
                last = Some(r);
            }
        }
    }
}

/// `RoundSkipped`-adjacent sequence pin: a fleet starved below a
/// full-quorum floor skips every round. The stream must carry one
/// `RoundSkipped` per planned round, in order, with no started/fused
/// rounds, followed by `JobFinished` — identically in sim and live,
/// and bit-reproducibly per seed.
#[test]
fn total_starvation_emits_skips_then_finishes() {
    let faults = FleetFaults {
        dropout_prob: 0.95,
        rejoin_after: 0,
        quorum_floor_frac: 1.0,
        ..FleetFaults::default()
    };
    for live in [false, true] {
        let evs = collect_events(live, "jit", faults, 0xD1, 6, 3);
        let skipped: Vec<u32> = evs
            .iter()
            .filter_map(|e| match e {
                SessionEvent::RoundSkipped { round, .. } => Some(*round),
                _ => None,
            })
            .collect();
        assert_eq!(skipped, vec![0, 1, 2], "live={live}: all rounds skip in order");
        assert!(
            !evs.iter().any(|e| matches!(
                e,
                SessionEvent::RoundStarted { .. } | SessionEvent::RoundFused { .. }
            )),
            "live={live}: nothing starts under total starvation"
        );
        let fin = evs
            .iter()
            .position(|e| matches!(e, SessionEvent::JobFinished { .. }))
            .expect("job still finishes");
        let last_skip = evs
            .iter()
            .rposition(|e| matches!(e, SessionEvent::RoundSkipped { .. }))
            .unwrap();
        assert!(last_skip < fin, "live={live}: skips precede the finish");
        assert_eq!(
            evs,
            collect_events(live, "jit", faults, 0xD1, 6, 3),
            "live={live}: the skip sequence is seed-deterministic"
        );
    }
}

/// Satellite pin: a consumer that subscribes and hangs up before (or
/// during) the run must not wedge or panic any emitter — the sink
/// latches closed and the live run completes normally.
#[test]
fn dropped_events_receiver_never_wedges_a_live_run() {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHomogeneous,
        8,
        3,
    );
    let mut s = Session::live().seed(0xDEAD).dim(16);
    let h = s.job(spec, "jit");
    drop(s.events());
    let rep = s.run().expect("run must survive a hung-up consumer");
    let o = rep.job(h);
    assert_eq!(o.records.len(), 3, "every round completes");
    assert!(o.updates_fused > 0);
}
