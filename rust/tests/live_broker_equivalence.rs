//! Sim/live **multi-job** equivalence through the `Session` façade: a
//! live session (wall-clock driver with a mocked instant clock, scripted
//! parties, per-job topic watching) must produce the *same* multi-tenant
//! schedule as a sim session for the same trace, seed and arbitration
//! policy.
//!
//! Both regimes run identical `JobEngine` + `Strategy` + admission +
//! arbitration code; what differs is only event delivery — the simulator
//! pre-schedules every arrival, while the live path publishes real
//! updates into per-job MQ topics and the driver ingests them back as
//! arrival events. If anything diverges — arrival times, cross-job event
//! routing, admission release order, policy-driven preemption — these
//! bit-for-bit comparisons break.

use fljit::broker::admission::AdmissionConfig;
use fljit::broker::arbitration;
use fljit::broker::workload::{poisson_trace, JobTrace, TraceConfig};
use fljit::coordinator::session::{Report, Session};

fn trace(seed: u64) -> JobTrace {
    poisson_trace(&TraceConfig {
        n_jobs: 4,
        mean_interarrival_secs: 8.0,
        party_mix: vec![(4, 0.6), (8, 0.4)],
        intermittent_frac: 0.25,
        rounds_lo: 2,
        rounds_hi: 2,
        t_wait_secs: 60.0,
        seed,
        ..Default::default()
    })
}

fn run_pair(policy: &str, seed: u64, capacity: usize, budget: usize) -> (Report, Report) {
    let t = trace(seed);
    let admission = AdmissionConfig {
        budget,
        max_jobs: 0,
        autoscale: None,
    };
    let sim = Session::sim()
        .trace(&t)
        .policy(policy)
        .admission(admission.clone())
        .capacity(capacity)
        .seed(seed)
        .run()
        .unwrap_or_else(|e| panic!("{policy}: sim broker run: {e:#}"));
    let live = Session::live()
        .trace(&t)
        .policy(policy)
        .admission(admission)
        .capacity(capacity)
        .seed(seed)
        .dim(16)
        .run()
        .unwrap_or_else(|e| panic!("{policy}: live broker run: {e:#}"));
    (sim, live)
}

fn assert_equivalent(policy: &str, seed: u64, capacity: usize, budget: usize) {
    let t = trace(seed);
    let (sim, live) = run_pair(policy, seed, capacity, budget);
    let (sim, live) = (sim.summary(), live.summary());

    assert_eq!(sim.jobs.len(), live.jobs.len(), "{policy}: job count");
    for (s, l) in sim.jobs.iter().zip(&live.jobs) {
        let job = s.job;
        assert_eq!(s.name, l.name, "{policy} job {job}");
        assert_eq!(
            s.records.len(),
            l.records.len(),
            "{policy} job {job}: round count"
        );
        for (a, b) in s.records.iter().zip(&l.records) {
            assert_eq!(a.round, b.round, "{policy} job {job}: round index");
            assert_eq!(
                a.latency_secs.to_bits(),
                b.latency_secs.to_bits(),
                "{policy} job {job} round {}: latency {} vs {}",
                a.round,
                a.latency_secs,
                b.latency_secs
            );
            assert_eq!(
                a.last_arrival_secs.to_bits(),
                b.last_arrival_secs.to_bits(),
                "{policy} job {job} round {}: last arrival",
                a.round
            );
            assert_eq!(
                a.complete_secs.to_bits(),
                b.complete_secs.to_bits(),
                "{policy} job {job} round {}: completion",
                a.round
            );
        }
        assert_eq!(
            s.queue_wait_secs.to_bits(),
            l.queue_wait_secs.to_bits(),
            "{policy} job {job}: admission queue wait {} vs {}",
            s.queue_wait_secs,
            l.queue_wait_secs
        );
        assert_eq!(
            s.updates_fused, l.updates_fused,
            "{policy} job {job}: emulated merge count"
        );
        assert_eq!(
            s.deployments, l.deployments,
            "{policy} job {job}: deployments"
        );
        assert_eq!(
            s.makespan_secs.to_bits(),
            l.makespan_secs.to_bits(),
            "{policy} job {job}: makespan {} vs {}",
            s.makespan_secs,
            l.makespan_secs
        );
        // the live path additionally folded every expected update for real
        let expected: u64 =
            (t.arrivals[job].spec.n_parties as u64) * t.arrivals[job].spec.rounds as u64;
        assert_eq!(l.updates_folded, expected, "{policy} job {job}: real folds");
    }
    assert_eq!(
        sim.span_secs.to_bits(),
        live.span_secs.to_bits(),
        "{policy}: span {} vs {}",
        sim.span_secs,
        live.span_secs
    );
    assert_eq!(
        sim.total_container_seconds.to_bits(),
        live.total_container_seconds.to_bits(),
        "{policy}: total container-seconds"
    );
    assert_eq!(
        sim.preemptions, live.preemptions,
        "{policy}: preemption decision order"
    );
}

#[test]
fn deadline_multijob_matches_sim() {
    assert_equivalent("deadline", 0xA1, 8, 64);
}

#[test]
fn least_slack_multijob_matches_sim() {
    assert_equivalent("least-slack", 0xA2, 8, 64);
}

#[test]
fn wfs_multijob_matches_sim() {
    assert_equivalent("wfs", 0xA3, 8, 64);
}

#[test]
fn scarce_capacity_with_backpressure_matches_sim() {
    // a single-slot admission budget serializes jobs (queue waits > 0 on
    // both sides, bit-identical) and a scarce cluster forces arbitrated
    // starts — the harshest cross-job interleaving
    for policy in arbitration::all_policies() {
        assert_equivalent(policy, 0xA4, 2, 1);
    }
}

#[test]
fn concurrent_jobs_overlap_in_both_regimes() {
    let (sim, live) = run_pair("deadline", 0xA5, 8, 64);
    let (sim, live) = (sim.summary(), live.summary());
    assert!(
        sim.max_concurrent_jobs() >= 2,
        "trace must overlap jobs (sim peak {})",
        sim.max_concurrent_jobs()
    );
    assert_eq!(
        sim.max_concurrent_jobs(),
        live.max_concurrent_jobs(),
        "peak concurrency"
    );
}
