//! Sim/live **multi-job** equivalence: the wall-clock driver with a
//! mocked instant clock, scripted parties and per-job topic watching must
//! produce the *same* multi-tenant schedule as the virtual-time platform
//! for the same trace, seed and arbitration policy.
//!
//! Both regimes run identical `JobEngine` + `Strategy` + admission +
//! arbitration code; what differs is only event delivery — the simulator
//! pre-schedules every arrival, while the live path publishes real
//! updates into per-job MQ topics and the driver ingests them back as
//! arrival events. If anything diverges — arrival times, cross-job event
//! routing, admission release order, policy-driven preemption — these
//! bit-for-bit comparisons break.

use std::sync::Arc;

use fljit::broker::admission::AdmissionConfig;
use fljit::broker::arbitration;
use fljit::broker::workload::{poisson_trace, JobTrace, TraceConfig};
use fljit::broker::{run_trace, BrokerConfig};
use fljit::coordinator::live::{run_live_broker, LiveBrokerConfig};
use fljit::mq::MessageQueue;

fn trace(seed: u64) -> JobTrace {
    poisson_trace(&TraceConfig {
        n_jobs: 4,
        mean_interarrival_secs: 8.0,
        party_mix: vec![(4, 0.6), (8, 0.4)],
        intermittent_frac: 0.25,
        rounds_lo: 2,
        rounds_hi: 2,
        t_wait_secs: 60.0,
        seed,
        ..Default::default()
    })
}

fn assert_equivalent(policy: &str, seed: u64, capacity: usize, budget: usize) {
    let t = trace(seed);
    let admission = AdmissionConfig {
        budget,
        max_jobs: 0,
    };
    let sim = run_trace(
        &t,
        &BrokerConfig {
            capacity,
            admission: admission.clone(),
            policy: policy.to_string(),
            seed,
            with_solo: false,
        },
    );
    let live = run_live_broker(
        &t,
        &LiveBrokerConfig {
            capacity,
            admission,
            policy: policy.to_string(),
            seed,
            dim: 16,
            ..Default::default()
        },
        &Arc::new(MessageQueue::new()),
        false,
    )
    .unwrap_or_else(|e| panic!("{policy}: live broker run: {e:#}"));

    assert_eq!(sim.jobs.len(), live.jobs.len(), "{policy}: job count");
    for (s, l) in sim.jobs.iter().zip(&live.jobs) {
        let job = s.job;
        assert_eq!(s.name, l.name, "{policy} job {job}");
        assert_eq!(
            s.report.rounds.len(),
            l.records.len(),
            "{policy} job {job}: round count"
        );
        for (a, b) in s.report.rounds.iter().zip(&l.records) {
            assert_eq!(a.round, b.round, "{policy} job {job}: round index");
            assert_eq!(
                a.latency_secs.to_bits(),
                b.latency_secs.to_bits(),
                "{policy} job {job} round {}: latency {} vs {}",
                a.round,
                a.latency_secs,
                b.latency_secs
            );
            assert_eq!(
                a.last_arrival_secs.to_bits(),
                b.last_arrival_secs.to_bits(),
                "{policy} job {job} round {}: last arrival",
                a.round
            );
            assert_eq!(
                a.complete_secs.to_bits(),
                b.complete_secs.to_bits(),
                "{policy} job {job} round {}: completion",
                a.round
            );
        }
        assert_eq!(
            s.queue_wait_secs.to_bits(),
            l.queue_wait_secs.to_bits(),
            "{policy} job {job}: admission queue wait {} vs {}",
            s.queue_wait_secs,
            l.queue_wait_secs
        );
        assert_eq!(
            s.report.updates_fused, l.updates_fused,
            "{policy} job {job}: emulated merge count"
        );
        assert_eq!(
            s.report.deployments, l.deployments,
            "{policy} job {job}: deployments"
        );
        assert_eq!(
            s.report.makespan_secs.to_bits(),
            l.makespan_secs.to_bits(),
            "{policy} job {job}: makespan {} vs {}",
            s.report.makespan_secs,
            l.makespan_secs
        );
        // the live path additionally folded every expected update for real
        let expected: u64 =
            (t.arrivals[job].spec.n_parties as u64) * t.arrivals[job].spec.rounds as u64;
        assert_eq!(l.updates_folded, expected, "{policy} job {job}: real folds");
    }
    assert_eq!(
        sim.span_secs.to_bits(),
        live.span_secs.to_bits(),
        "{policy}: span {} vs {}",
        sim.span_secs,
        live.span_secs
    );
    assert_eq!(
        sim.total_container_seconds.to_bits(),
        live.total_container_seconds.to_bits(),
        "{policy}: total container-seconds"
    );
    assert_eq!(
        sim.preemptions, live.preemptions,
        "{policy}: preemption decision order"
    );
}

#[test]
fn deadline_multijob_matches_sim() {
    assert_equivalent("deadline", 0xA1, 8, 64);
}

#[test]
fn least_slack_multijob_matches_sim() {
    assert_equivalent("least-slack", 0xA2, 8, 64);
}

#[test]
fn wfs_multijob_matches_sim() {
    assert_equivalent("wfs", 0xA3, 8, 64);
}

#[test]
fn scarce_capacity_with_backpressure_matches_sim() {
    // a single-slot admission budget serializes jobs (queue waits > 0 on
    // both sides, bit-identical) and a scarce cluster forces arbitrated
    // starts — the harshest cross-job interleaving
    for policy in arbitration::all_policies() {
        assert_equivalent(policy, 0xA4, 2, 1);
    }
}

#[test]
fn concurrent_jobs_overlap_in_both_regimes() {
    let t = trace(0xA5);
    let sim = run_trace(
        &t,
        &BrokerConfig {
            capacity: 8,
            admission: AdmissionConfig {
                budget: 64,
                max_jobs: 0,
            },
            policy: "deadline".to_string(),
            seed: 0xA5,
            with_solo: false,
        },
    );
    let live = run_live_broker(
        &t,
        &LiveBrokerConfig {
            capacity: 8,
            admission: AdmissionConfig {
                budget: 64,
                max_jobs: 0,
            },
            policy: "deadline".to_string(),
            seed: 0xA5,
            dim: 16,
            ..Default::default()
        },
        &Arc::new(MessageQueue::new()),
        false,
    )
    .expect("live run");
    assert!(
        sim.max_concurrent_jobs() >= 2,
        "trace must overlap jobs (sim peak {})",
        sim.max_concurrent_jobs()
    );
    assert_eq!(
        sim.max_concurrent_jobs(),
        live.max_concurrent_jobs(),
        "peak concurrency"
    );
}
