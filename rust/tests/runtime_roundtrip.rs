//! Integration: the AOT bridge end to end — load HLO-text artifacts on the
//! PJRT CPU client, execute, and pin the numerics against the pure-Rust
//! fusion path (which pytest pins against the jnp oracle, closing the
//! three-way pallas ≡ jnp ≡ rust consistency loop).
//!
//! Requires `make artifacts`; every test skips gracefully if absent so
//! `cargo test` stays green on a fresh checkout.

use fljit::fusion;
use fljit::runtime::{Runtime, Trainer, XlaFusion};
use fljit::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !fljit::runtime::xla_enabled() {
        eprintln!("skipping: built without the `xla` feature");
        return None;
    }
    let dir = fljit::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    // With the vendored xla_extension *stub* the feature compiles but the
    // PJRT client cannot construct — skip on the stub's distinctive error
    // only, so a real-crate PJRT/manifest regression still fails loudly
    // instead of silently turning the suite into skips.
    match Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("xla_extension stub"),
                "PJRT runtime failed for a non-stub reason: {msg}"
            );
            eprintln!("skipping: offline xla stub active ({msg})");
            None
        }
    }
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v);
    v
}

/// PR 2: with the vendored `xla_extension` stub, `--features xla` builds
/// offline, so these assertions are compiled (not skipped at the feature
/// gate) and run identically against the stub and the real crate.
#[cfg(feature = "xla")]
mod xla_feature_gate {
    #[test]
    fn feature_flag_reports_enabled() {
        assert!(fljit::runtime::xla_enabled());
    }

    #[test]
    fn runtime_init_without_artifacts_errors_cleanly() {
        // A directory with no manifest must yield a descriptive error —
        // both the stub and the real crate take this path — never a panic.
        let err = fljit::runtime::Runtime::new(std::path::Path::new(
            "/nonexistent-artifact-dir",
        ))
        .err()
        .expect("Runtime::new must fail without artifacts");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("manifest") || msg.contains("artifacts"),
            "unhelpful error: {msg}"
        );
    }
}

#[test]
fn pair_merge_xla_matches_rust() {
    let Some(rt) = runtime() else { return };
    let fx = XlaFusion::new(&rt);
    let mut rng = Rng::new(11);
    // exercises padding (non-multiple of the 65536 chunk) and chunking
    for n in [1000usize, 65536, 65536 + 123, 3 * 65536] {
        let a = rand_vec(&mut rng, n);
        let b = rand_vec(&mut rng, n);
        let (wa, wb) = (3.0f32, 2.0f32);
        let mut xla_acc = a.clone();
        fx.pair_merge(&mut xla_acc, wa, &b, wb).expect("xla pair_merge");
        let mut rust_acc = a.clone();
        fusion::pair_merge_into(&mut rust_acc, wa, &b, wb);
        for (i, (x, r)) in xla_acc.iter().zip(rust_acc.iter()).enumerate() {
            assert!(
                (x - r).abs() < 1e-4,
                "n={n} elem {i}: xla {x} vs rust {r}"
            );
        }
    }
}

#[test]
fn weighted_mean_xla_matches_rust_many_k() {
    let Some(rt) = runtime() else { return };
    let fx = XlaFusion::new(&rt);
    let mut rng = Rng::new(13);
    // k=12 forces the grouped/recursive path (artifact fan-in is 8)
    for k in [1usize, 3, 8, 12, 20] {
        let n = 70_000; // crosses the chunk boundary
        let updates: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(&mut rng, n)).collect();
        let views: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
        let w: Vec<f32> = (0..k).map(|i| 1.0 + i as f32 * 0.5).collect();
        let got = fx.weighted_mean(&views, &w).expect("xla weighted_mean");
        let want = fusion::weighted_mean(&views, &w);
        let mut max_err = 0.0f32;
        for (x, r) in got.iter().zip(want.iter()) {
            max_err = max_err.max((x - r).abs());
        }
        assert!(max_err < 1e-3, "k={k} max err {max_err}");
    }
}

#[test]
fn fedprox_xla_matches_rust() {
    let Some(rt) = runtime() else { return };
    let fx = XlaFusion::new(&rt);
    let mut rng = Rng::new(17);
    let n = 65536;
    let updates: Vec<Vec<f32>> = (0..4).map(|_| rand_vec(&mut rng, n)).collect();
    let views: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
    let w = [1.0f32, 2.0, 3.0, 4.0];
    let g = rand_vec(&mut rng, n);
    let mu = 0.3f32;
    let got = fx.fedprox(&views, &w, &g, mu).expect("xla fedprox");
    let want = fusion::fedprox_merge(&views, &w, &g, mu);
    for (x, r) in got.iter().zip(want.iter()) {
        assert!((x - r).abs() < 1e-3);
    }
}

#[test]
fn trainer_learns_on_synthetic_task() {
    let Some(rt) = runtime() else { return };
    let (x, y) = fljit::party::synth_party_dataset(0, 256, 64, 10, 50.0, 7);
    let mut t = Trainer::init(&rt, 7);
    let (loss0, acc0) = t.eval(&x, &y).expect("eval");
    // 20 SGD steps on the same batch of 32
    let (bx, by) = fljit::party::synth_party_dataset(1, 32, 64, 10, 50.0, 7);
    let mut last = f32::INFINITY;
    for _ in 0..20 {
        last = t.step(32, &bx, &by, 0.1).expect("step");
    }
    let (loss1, acc1) = t.eval(&x, &y).expect("eval");
    assert!(last.is_finite());
    assert!(loss1 < loss0, "loss {loss0} -> {loss1}");
    assert!(acc1 >= acc0, "acc {acc0} -> {acc1}");
}

#[test]
fn trainer_epoch_matches_shapes_and_flattens() {
    let Some(rt) = runtime() else { return };
    let (xs, ys) = fljit::party::synth_party_dataset(2, 8 * 32, 64, 10, 1.0, 9);
    let mut t = Trainer::init(&rt, 9);
    let flat0 = t.flatten();
    assert_eq!(flat0.len(), fljit::model::zoo::mlp_default().total_params());
    let loss = t.epoch(8, &xs, &ys, 0.05).expect("epoch");
    assert!(loss.is_finite() && loss > 0.0);
    let flat1 = t.flatten();
    assert_ne!(flat0, flat1, "epoch must change parameters");
    // unflatten round-trips
    let mut t2 = Trainer::init(&rt, 1);
    t2.unflatten(&flat1);
    assert_eq!(t2.flatten(), flat1);
}

#[test]
fn streaming_aggregator_over_xla_matches_tree_reduce() {
    let Some(rt) = runtime() else { return };
    let fx = XlaFusion::new(&rt);
    let spec = fljit::model::ModelSpec::new("t", vec![("l", 40_000)]);
    let mut rng = Rng::new(23);
    let updates: Vec<fljit::model::ModelUpdate> = (0..6)
        .map(|i| fljit::model::ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
        .collect();
    // stream through XLA pair merges (the live platform's hot path)
    let mut acc = updates[0].data.clone();
    let mut w_acc = updates[0].weight;
    for u in &updates[1..] {
        fx.pair_merge(&mut acc, w_acc, &u.data, u.weight).unwrap();
        w_acc += u.weight;
    }
    let tree = fusion::tree_reduce(&updates, 3);
    for (x, r) in acc.iter().zip(tree.acc.iter()) {
        assert!((x - r).abs() < 1e-3);
    }
}
