//! Minimal, API-compatible subset of the `anyhow` crate.
//!
//! The offline build image ships no crates.io registry, so the workspace
//! carries this shim as a path dependency. It covers exactly the surface
//! `fljit` uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait. Error values
//! are a context chain of strings; `{e}` prints the outermost message and
//! `{e:#}` prints the whole chain separated by `": "`, mirroring anyhow's
//! Display behaviour.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a layer of context (the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result`'s error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("outer {}", 1);
        assert_eq!(format!("{e}"), "outer 1");
        let e = e.context("context");
        assert_eq!(format!("{e}"), "context");
        assert_eq!(format!("{e:#}"), "context: outer 1");
        assert_eq!(format!("{e:?}"), "context: outer 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing thing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("missing thing"));

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("want {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "want 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
    }
}
