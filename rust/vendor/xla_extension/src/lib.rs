//! Offline **stub** of the `xla` (xla_extension) PJRT bindings.
//!
//! The real crate links the PJRT C API and an XLA build — hundreds of MB
//! of toolchain the offline image cannot ship. This stub mirrors exactly
//! the API surface `fljit::runtime` consumes, so `cargo check/test
//! --features xla` works without the network; every entry point that
//! would reach PJRT returns a descriptive [`Error`] instead. The one
//! constructor callers hit first, [`PjRtClient::cpu`], fails immediately,
//! so no stubbed object is ever observable in a live code path.
//!
//! To run the real PJRT paths, point the `xla` path dependency in
//! `rust/Cargo.toml` at an actual xla_extension checkout; the signatures
//! below are drop-in compatible with the subset used.

use std::fmt;

/// Error mirroring the real crate's (callers only rely on `Debug`).
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: xla_extension stub (offline build) — no PJRT runtime available"
            ),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub constructor always fails, which is what
/// keeps every downstream object unreachable at runtime.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module (text form in the real crate).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled executable resident on a PJRT device.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal (typed nd-array).
#[derive(Clone, Debug)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::decompose_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_point_errors_clearly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let mut lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.decompose_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "unhelpful stub error: {msg}");
    }
}
