# Build-time helpers. The request path is pure Rust; Python only runs
# here, once, to AOT-lower the JAX graphs to HLO text (see
# python/compile/aot.py and rust/src/runtime).

.PHONY: artifacts test

# HLO text artifacts + manifest.json for the XLA runtime
# (`--features xla`). Requires a Python env with jax installed.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Tier-1 verification.
test:
	cargo build --release && cargo test -q
